PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test bench bench-decode bench-smoke lint

# tier-1 verify (ROADMAP.md)
test:
	$(PYTHON) -m pytest -x -q

# serving throughput + vectorized simulator; writes BENCH_serving.json
bench:
	$(PYTHON) benchmarks/serving_throughput.py

# cached decode vs stateless re-prefill; writes BENCH_decode.json
bench-decode:
	$(PYTHON) benchmarks/decode_throughput.py

# CI-sized decode bench: tiny workload, asserts the cached/stateless/
# monolithic outputs agree and the BENCH_decode.json schema holds
bench-smoke:
	$(PYTHON) benchmarks/decode_throughput.py --smoke --out /tmp/BENCH_decode_smoke.json

# syntax check of every tree (no third-party linter baked into the image;
# swap in ruff/pyflakes here once available)
lint:
	$(PYTHON) -m compileall -q src tests benchmarks examples
