PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-cov fuzz bench bench-decode bench-paged bench-control bench-smoke lint

# tier-1 verify (ROADMAP.md)
test:
	$(PYTHON) -m pytest -x -q

# tier-1 with line coverage gate (needs pytest-cov from requirements-dev.txt)
test-cov:
	$(PYTHON) -m pytest -q --cov=repro --cov-fail-under=70

# seeded hypothesis fuzz of the BlockAllocator properties (~2 min in CI)
fuzz:
	HYPOTHESIS_PROFILE=ci-fuzz $(PYTHON) -m pytest -q tests/test_paging_properties.py --hypothesis-seed=0

# serving throughput + vectorized simulator; writes BENCH_serving.json
bench:
	$(PYTHON) benchmarks/serving_throughput.py

# cached decode vs stateless re-prefill; writes BENCH_decode.json
bench-decode:
	$(PYTHON) benchmarks/decode_throughput.py

# paged vs dense slot caches at equal KV bytes; writes BENCH_paged.json
bench-paged:
	$(PYTHON) benchmarks/decode_throughput.py --cache-layout paged

# closed-loop vs static-once DTO-EE over the live engine, threshold-aware
# packing vs FIFO, simulator event-harvest A/B; writes BENCH_control.json
bench-control:
	$(PYTHON) benchmarks/control_loop.py

# CI-sized benches: tiny workloads, assert the cached/stateless/monolithic
# outputs agree (paged == dense bitwise with >= 2x in-flight at equal KV
# bytes; fifo == threshold packing token-identical with no extra padding;
# closed loop reconfigures with accuracy pinned) and the JSON schemas hold.
# Also emits a Perfetto trace of a small serve and gates it on the
# check_trace.py span invariants.  Outputs land in bench-artifacts/ so CI
# can upload them per PR.
bench-smoke:
	mkdir -p bench-artifacts
	$(PYTHON) benchmarks/decode_throughput.py --smoke --out bench-artifacts/BENCH_decode_smoke.json
	$(PYTHON) benchmarks/decode_throughput.py --smoke --cache-layout paged --out bench-artifacts/BENCH_paged_smoke.json
	$(PYTHON) benchmarks/control_loop.py --smoke --out bench-artifacts/BENCH_control_smoke.json
	$(PYTHON) -m repro.launch.serve --slots 1 --requests-per-slot 8 --gen-len 2 \
		--trace-out bench-artifacts/trace_smoke.json \
		--stats-report bench-artifacts/serve_report_smoke.json
	$(PYTHON) tools/check_trace.py bench-artifacts/trace_smoke.json

# syntax check of every tree (no third-party linter baked into the image;
# swap in ruff/pyflakes here once available)
lint:
	$(PYTHON) -m compileall -q src tests benchmarks examples
