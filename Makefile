PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test bench lint

# tier-1 verify (ROADMAP.md)
test:
	$(PYTHON) -m pytest -x -q

# serving throughput + vectorized simulator; writes BENCH_serving.json
bench:
	$(PYTHON) benchmarks/serving_throughput.py

# syntax check of every tree (no third-party linter baked into the image;
# swap in ruff/pyflakes here once available)
lint:
	$(PYTHON) -m compileall -q src tests benchmarks examples
