#!/usr/bin/env python
"""Validate a Chrome-trace/Perfetto JSON file emitted by the serving engine.

    python tools/check_trace.py trace.json [more.json ...]

Checks the Trace Event Format schema and the engine's span invariants
(non-negative durations, no unclosed B/E spans, per-request tracks monotone
and non-overlapping) via :func:`repro.obs.export.validate_chrome_trace`.
Exit code 0 when every file passes, 1 otherwise — the CI gate behind
``make bench-smoke``'s trace artifact.
"""
from __future__ import annotations

import json
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent / "src"))

from repro.obs.export import validate_chrome_trace  # noqa: E402


def main(argv: list[str]) -> int:
    if not argv:
        print("usage: check_trace.py TRACE.json [TRACE.json ...]")
        return 2
    failed = False
    for path in argv:
        try:
            with open(path) as f:
                payload = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"{path}: unreadable ({e})")
            failed = True
            continue
        errs = validate_chrome_trace(payload)
        if errs:
            failed = True
            print(f"{path}: {len(errs)} violation(s)")
            for e in errs[:20]:
                print(f"  {e}")
            if len(errs) > 20:
                print(f"  ... and {len(errs) - 20} more")
        else:
            n = len(payload["traceEvents"])
            print(f"{path}: OK ({n} events)")
    return 1 if failed else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
