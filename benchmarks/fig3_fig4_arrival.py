"""Figs. 3-4: delay + accuracy vs. task arrival rate (ResNet101 & BERT).

For each arrival-rate scale, every algorithm gets a configuration phase
(with its own threshold adaptation) and one measured 5 s offloading slot.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import ALGOS, decide, fmt_row, run_slot
from repro.core.thresholds import synthetic_validation
from repro.core.topology import build_edge_network
from repro.core.types import BERT_PROFILE, DtoHyperParams, RESNET101_PROFILE

SCALES = {
    "resnet101": (2.0, 2.5, 3.0, 3.5),
    "bert": (0.5, 0.65, 0.8, 0.95),
}


def run(seed: int = 0, duration: float = 5.0) -> list[str]:
    hyper = DtoHyperParams()
    lines = []
    results = {}
    for profile in (RESNET101_PROFILE, BERT_PROFILE):
        exit_profile = synthetic_validation(seed=seed + 1, profile=profile)
        for scale in SCALES[profile.name]:
            topo = build_edge_network(
                seed=seed, profile=profile, arrival_rate_scale=scale
            )
            rate = topo.phi_ext.sum()
            lines.append(f"--- {profile.name} arrival {rate:.1f} tasks/s ---")
            for algo in ALGOS:
                state = decide(algo, topo, profile, exit_profile, hyper, None, static=True)
                sim = run_slot(
                    topo, profile, exit_profile, state, None, duration, seed + 42
                )
                results[(profile.name, scale, algo)] = sim
                lines.append(fmt_row(algo, sim))
        # headline: reduction at the highest load
        top = SCALES[profile.name][-1]
        d_dto = results[(profile.name, top, "DTO-EE")].mean_delay
        reds = {
            a: (1 - d_dto / results[(profile.name, top, a)].mean_delay) * 100
            for a in ALGOS
            if a != "DTO-EE"
        }
        accs = {
            a: (
                results[(profile.name, top, "DTO-EE")].accuracy
                - results[(profile.name, top, a)].accuracy
            )
            * 100
            for a in ALGOS
            if a != "DTO-EE"
        }
        lines.append(
            f"[{profile.name}] DTO-EE delay reduction at top load: "
            + ", ".join(f"{a} {v:.0f}%" for a, v in reds.items())
            + "  |  accuracy delta (pts): "
            + ", ".join(f"{a} {v:+.1f}" for a, v in accs.items())
        )
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
