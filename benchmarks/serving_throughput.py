"""End-to-end serving throughput: micro-batched data plane vs batch-1.

Measures wall-clock tokens/s and simulated mean/p95 response delay of
``CollaborativeEngine.serve`` at micro-batch sizes {1, 8, 32} on one fixed
workload (same prompts, same arrival process, same thresholds), checks that
every batch size makes identical exit decisions, runs a tracing-overhead A/B
(span tracer on vs off, identical seeds: bitwise-identical results, <3%
tokens/s budget), and times the vectorized discrete-event simulator on a
~1e4-task slot.  Results land in ``BENCH_serving.json`` so the perf
trajectory is tracked PR over PR.

    PYTHONPATH=src python benchmarks/serving_throughput.py [--out BENCH_serving.json]
"""
from __future__ import annotations

import argparse
import json
import platform
import time

import numpy as np

import jax

from repro.configs import get_config
from repro.core import simulator
from repro.core.profiles import profile_from_arch
from repro.core.thresholds import synthetic_validation
from repro.core.topology import NetworkSpec, build_edge_network
from repro.core.types import DtoHyperParams, RESNET101_PROFILE
from repro.models import model as model_lib
from repro.serving import CollaborativeEngine


def build_engine(seed: int = 0) -> CollaborativeEngine:
    """A small-but-real staged model: per-dispatch overhead vs per-row compute
    at a ratio representative of a serving host driving an accelerator."""
    cfg = get_config("stablelm-1.6b").reduced(
        vocab_size=128,
        d_model=64,
        d_ff=128,
        num_heads=2,
        num_kv_heads=2,
        head_dim=32,
    )
    params = model_lib.init_params(jax.random.key(0), cfg)
    profile = profile_from_arch(cfg)
    topo = build_edge_network(
        seed=seed, profile=profile, spec=NetworkSpec(num_eds=4, es_per_stage=(2, 2))
    )
    ep = synthetic_validation(seed=1, profile=profile)
    eng = CollaborativeEngine(
        params, cfg, topo, profile, ep, DtoHyperParams(rounds=20), seed=seed
    )
    eng.configuration_phase()
    return eng


def bench_engine(
    eng: CollaborativeEngine,
    batch_sizes: tuple[int, ...],
    n_requests: int,
    prompt_len: int,
    arrival_rate: float,
    serve_seed: int = 123,
    repeats: int = 5,
) -> dict:
    rng = np.random.default_rng(0)
    prompts = [
        rng.integers(0, eng.cfg.vocab_size, size=prompt_len).astype(np.int32)
        for _ in range(n_requests)
    ]
    per_bs: dict[str, dict] = {}
    exits: dict[int, dict] = {}
    for bs in batch_sizes:
        eng.rng = np.random.default_rng(serve_seed)
        eng.serve(prompts, arrival_rate=arrival_rate, batch_size=bs)  # warmup/compile
        walls = []
        for _ in range(repeats):
            eng.rng = np.random.default_rng(serve_seed)
            t0 = time.perf_counter()
            stats = eng.serve(prompts, arrival_rate=arrival_rate, batch_size=bs)
            walls.append(time.perf_counter() - t0)
        wall = float(np.median(walls))  # median-of-N: robust to box noise
        s = stats.summary()
        exits[bs] = stats.by_rid()
        per_bs[str(bs)] = {
            "wall_s": wall,
            "tokens_per_s": s["num_completed"] / wall,
            "num_completed": s["num_completed"],
            "mean_delay_s": s["mean_delay"],
            "p95_delay_s": s["p95_delay"],
            "num_batches": s["num_batches"],
            "num_forward_rows": s["num_forward_rows"],
            "num_real_rows": s["num_real_rows"],
            "padded_row_frac": s["padded_row_frac"],
            "sim_tokens_per_s": s["sim_tokens_per_s"],
        }
        print(
            f"batch {bs:3d}: {per_bs[str(bs)]['tokens_per_s']:8.1f} tok/s  "
            f"wall {wall:.3f}s  batches {s['num_batches']:4d}  "
            f"mean delay {s['mean_delay'] * 1e3:7.1f} ms  "
            f"p95 {s['p95_delay'] * 1e3:7.1f} ms  "
            f"padded waste {s['padded_row_frac'] * 100:4.1f}% "
            f"({s['num_forward_rows'] - s['num_real_rows']}/{s['num_forward_rows']} rows)"
        )
    b0 = min(batch_sizes)
    identical = all(exits[bs] == exits[b0] for bs in batch_sizes)
    bmax = max(batch_sizes)
    speedup = (
        per_bs[str(bmax)]["tokens_per_s"] / per_bs[str(b0)]["tokens_per_s"]
    )
    print(f"exit decisions identical across batch sizes: {identical}")
    print(f"speedup batch {bmax} vs {b0}: {speedup:.2f}x")
    return {
        "workload": {
            "n_requests": n_requests,
            "prompt_len": prompt_len,
            "arrival_rate": arrival_rate,
        },
        "by_batch_size": per_bs,
        "exits_identical": identical,
        "speedup_maxbatch_vs_1": speedup,
    }


def bench_tracing(
    eng: CollaborativeEngine,
    n_requests: int,
    prompt_len: int,
    arrival_rate: float,
    batch_size: int = 8,
    serve_seed: int = 123,
    repeats: int = 5,
    budget_frac: float = 0.03,
) -> dict:
    """Tracing-overhead A/B: tracer on vs off, identical seeds.

    With observers disabled ``build_stream`` returns ``None`` and every
    instrumentation site is a single ``is not None`` test, so the disabled
    path must be BITWISE identical to the pre-observability engine — checked
    here on exit decisions and delays.  With the tracer attached the budget
    is <3% tokens/s regression; runs are interleaved and min-of-N (the
    noise-robust wall estimator — medians on a shared box swing more than
    the effect being measured).  The full tracer+metrics stack is recorded
    as an extra row, ungated.

    The default A/B prompt length (32) is deliberately longer than the main
    throughput sweep's: per-event tracing cost is fixed, so the 4-token
    workload — a dispatch-overhead stress test — would measure tracing
    against artificially tiny per-batch compute rather than representative
    stage work.
    """
    from repro.obs import MetricsCollector, SpanTracer

    rng = np.random.default_rng(0)
    prompts = [
        rng.integers(0, eng.cfg.vocab_size, size=prompt_len).astype(np.int32)
        for _ in range(n_requests)
    ]
    modes = ("off", "tracer", "tracer+metrics")

    def run(mode: str):
        eng.rng = np.random.default_rng(serve_seed)
        tracer = SpanTracer() if mode != "off" else None
        metrics = MetricsCollector() if mode == "tracer+metrics" else None
        t0 = time.perf_counter()
        stats = eng.serve(
            prompts,
            arrival_rate=arrival_rate,
            batch_size=batch_size,
            tracer=tracer,
            metrics=metrics,
        )
        return time.perf_counter() - t0, stats

    run("off")  # warmup/compile
    walls: dict[str, list[float]] = {m: [] for m in modes}
    last: dict[str, object] = {}
    for _ in range(repeats):
        for m in modes:  # interleaved: drift hits every mode equally
            w, last[m] = run(m)
            walls[m].append(w)
    wall = {m: float(np.min(walls[m])) for m in modes}
    # disabled path == traced path: same exits, same delays, bit for bit
    identical = all(
        last["off"].by_rid() == last[m].by_rid()
        and all(a == b for a, b in zip(last["off"].delays, last[m].delays))
        for m in modes[1:]
    )
    n_done = last["off"].summary()["num_completed"]
    overhead = {m: wall[m] / wall["off"] - 1.0 for m in modes[1:]}
    res = {
        "workload": {
            "n_requests": n_requests,
            "prompt_len": prompt_len,
            "batch_size": batch_size,
            "repeats": repeats,
        },
        "by_mode": {
            m: {
                "wall_s": wall[m],
                "tokens_per_s": n_done / wall[m],
                "overhead_frac": overhead.get(m, 0.0),
            }
            for m in modes
        },
        "budget_frac": budget_frac,
        "within_budget": overhead["tracer"] <= budget_frac,
        "results_bitwise_identical": identical,
        "spans_recorded": sum(
            len(v) for v in last["tracer"].trace.spans.values()
        ),
    }
    for m in modes:
        print(
            f"tracing A/B {m:15s}: {n_done / wall[m]:8.1f} tok/s  "
            f"overhead {overhead.get(m, 0.0) * 100:+.2f}%"
        )
    print(
        f"tracing A/B: bitwise identical {identical}  "
        f"spans {res['spans_recorded']}"
    )
    assert identical, "traced serve diverged from untraced serve"
    if not res["within_budget"]:
        print(
            f"WARNING: tracer overhead {overhead['tracer'] * 100:.2f}% "
            f"exceeds {budget_frac * 100:.0f}% budget"
        )
    return res


def bench_simulator(arrival_rate_scale: float = 12.0, duration: float = 20.0) -> dict:
    """Vectorized discrete-event simulator on a heavily loaded slot."""
    profile = RESNET101_PROFILE
    topo = build_edge_network(
        seed=0, profile=profile, arrival_rate_scale=arrival_rate_scale
    )
    ep = synthetic_validation(seed=1, profile=profile)
    p = np.ones(topo.num_edges, np.float64)
    thr = np.full(ep.num_early_branches, 0.8)
    t0 = time.perf_counter()
    res = simulator.simulate_slot(
        topo, profile, ep, p, thr, duration=duration, seed=3
    )
    wall = time.perf_counter() - t0
    out = {
        "arrival_rate_scale": arrival_rate_scale,
        "duration_s": duration,
        "generated": res.generated,
        "completed": res.completed,
        "wall_s": wall,
        "tasks_per_s": res.completed / wall,
        "mean_delay_s": res.mean_delay,
    }
    print(
        f"simulator: {res.completed} tasks in {wall:.2f}s "
        f"({out['tasks_per_s']:.0f} tasks/s)"
    )
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="BENCH_serving.json")
    ap.add_argument("--n-requests", type=int, default=256)
    ap.add_argument("--prompt-len", type=int, default=4)
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument(
        "--ab-prompt-len",
        type=int,
        default=32,
        help="prompt length for the tracing-overhead A/B (longer than the "
        "throughput sweep's: fixed per-event tracing cost is measured "
        "against representative per-batch compute)",
    )
    ap.add_argument(
        "--batch-sizes", type=int, nargs="+", default=[1, 8, 32]
    )
    ap.add_argument(
        "--arrival-rate",
        type=float,
        default=1e6,
        help="Poisson arrival rate; high = closed-loop (all requests queued)",
    )
    args = ap.parse_args()

    eng = build_engine()
    engine_res = bench_engine(
        eng,
        tuple(args.batch_sizes),
        args.n_requests,
        args.prompt_len,
        args.arrival_rate,
        repeats=args.repeats,
    )
    tracing_res = bench_tracing(
        eng,
        args.n_requests,
        args.ab_prompt_len,
        args.arrival_rate,
        repeats=args.repeats,
    )
    sim_res = bench_simulator()
    payload = {
        "engine": engine_res,
        "tracing_overhead": tracing_res,
        "simulator": sim_res,
        "meta": {
            "jax": jax.__version__,
            "backend": jax.default_backend(),
            "platform": platform.platform(),
        },
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
