"""Decode throughput: cache-threaded decode vs stateless re-prefill, and
paged vs dense slot-cache capacity.

Default mode runs ``CollaborativeEngine.serve`` at gen_len in {8, 32} in
both decode modes on one fixed workload (same prompts, same arrival process,
same thresholds), asserts token-identical sequences and exit decisions
between the modes AND against the monolithic ``model.prefill`` +
``model.decode_step`` reference, and measures wall-clock decode tokens/s.
A traced run then joins measured per-stage wall time with the analytic
roofline FLOP/byte counts into per-(stage, phase) utilization rows.
Results land in ``BENCH_decode.json``.

``--cache-layout paged`` instead A/Bs the PAGED slot store against the dense
layout at EQUAL KV bytes (same pool token capacity as the dense arenas) on a
production-shaped workload — mixed prompt lengths plus shared-prefix groups —
asserts bitwise-identical tokens, and records how many more requests the
paged replica holds in flight in the same memory, with prefix-hit and
block-occupancy stats.  Results land in ``BENCH_paged.json``.

    PYTHONPATH=src python benchmarks/decode_throughput.py [--out BENCH_decode.json]
    PYTHONPATH=src python benchmarks/decode_throughput.py --cache-layout paged
    PYTHONPATH=src python benchmarks/decode_throughput.py --smoke   # CI schema check
"""
from __future__ import annotations

import argparse
import json
import platform
import time

import numpy as np

import jax

from repro.configs import get_config
from repro.core.profiles import profile_from_arch
from repro.core.thresholds import synthetic_validation
from repro.core.topology import NetworkSpec, build_edge_network
from repro.core.types import DtoHyperParams
from repro.models import model as model_lib
from repro.serving import CollaborativeEngine, monolithic_generate


def build_engine(seed: int = 0, threshold: float | None = 0.1) -> CollaborativeEngine:
    """A small-but-real staged model: per-dispatch overhead vs per-row compute
    at a ratio representative of a serving host driving an accelerator."""
    cfg = get_config("stablelm-1.6b").reduced(
        vocab_size=128,
        d_model=64,
        d_ff=128,
        num_heads=2,
        num_kv_heads=2,
        head_dim=32,
    )
    params = model_lib.init_params(jax.random.key(0), cfg)
    profile = profile_from_arch(cfg)
    topo = build_edge_network(
        seed=seed, profile=profile, spec=NetworkSpec(num_eds=4, es_per_stage=(2, 2))
    )
    ep = synthetic_validation(seed=1, profile=profile)
    eng = CollaborativeEngine(
        params, cfg, topo, profile, ep, DtoHyperParams(rounds=20), seed=seed
    )
    eng.configuration_phase()
    if threshold is not None:
        # a mid-range threshold so the workload mixes early exits (rows
        # retiring mid-batch) with full-length generations
        eng.state.thresholds = np.full_like(eng.state.thresholds, threshold)
    return eng


def bench_decode(
    eng: CollaborativeEngine,
    gen_lens: tuple[int, ...],
    n_requests: int,
    prompt_len: int,
    batch_size: int,
    arrival_rate: float,
    serve_seed: int = 123,
    repeats: int = 2,
    num_slots: int | None = None,
) -> dict:
    rng = np.random.default_rng(0)
    prompts = [
        rng.integers(0, eng.cfg.vocab_size, size=prompt_len).astype(np.int32)
        for _ in range(n_requests)
    ]
    by_gen: dict[str, dict] = {}
    for gen_len in gen_lens:
        # monolithic single-host reference: the ground truth both engine
        # modes must reproduce token-for-token
        reference = {}
        for i, p in enumerate(prompts):
            toks, stage = monolithic_generate(
                eng.programs.params, eng.cfg, p, eng.thresholds, gen_len
            )
            reference[i] = (stage, tuple(toks))
        modes: dict[str, dict] = {}
        seqs: dict[str, dict] = {}
        for mode in ("stateless", "cached"):
            eng.rng = np.random.default_rng(serve_seed)
            eng.serve(
                prompts,
                arrival_rate=arrival_rate,
                batch_size=batch_size,
                gen_len=gen_len,
                decode_mode=mode,
                num_slots=num_slots,
            )  # warmup/compile
            walls = []
            for _ in range(repeats):
                eng.rng = np.random.default_rng(serve_seed)
                t0 = time.perf_counter()
                stats = eng.serve(
                    prompts,
                    arrival_rate=arrival_rate,
                    batch_size=batch_size,
                    gen_len=gen_len,
                    decode_mode=mode,
                    num_slots=num_slots,
                )
                walls.append(time.perf_counter() - t0)
            wall = float(np.median(walls))
            s = stats.summary()
            seqs[mode] = stats.sequences_by_rid()
            modes[mode] = {
                "wall_s": wall,
                "tokens_per_s": s["generated_tokens"] / wall,
                "generated_tokens": s["generated_tokens"],
                "num_completed": s["num_completed"],
                "mean_delay_s": s["mean_delay"],
                "p95_delay_s": s["p95_delay"],
                "num_batches": s["num_batches"],
                "padded_row_frac": s["padded_row_frac"],
                "exit_histogram": s["exit_histogram"],
            }
            print(
                f"gen_len {gen_len:3d} {mode:9s}: "
                f"{modes[mode]['tokens_per_s']:8.1f} tok/s  wall {wall:.3f}s  "
                f"batches {s['num_batches']:5d}  exits {s['exit_histogram']}"
            )
        identical = (
            seqs["cached"] == seqs["stateless"] == reference
        )
        speedup = modes["cached"]["tokens_per_s"] / modes["stateless"]["tokens_per_s"]
        print(
            f"gen_len {gen_len:3d}: token-identical (cached == stateless == "
            f"monolithic): {identical}  speedup {speedup:.2f}x"
        )
        by_gen[str(gen_len)] = {
            "by_mode": modes,
            "tokens_identical": identical,
            "speedup_cached_vs_stateless": speedup,
        }
    return {
        "workload": {
            "n_requests": n_requests,
            "prompt_len": prompt_len,
            "batch_size": batch_size,
            "num_slots": num_slots,
            "arrival_rate": arrival_rate,
            "threshold": float(eng.thresholds[0]),
        },
        "by_gen_len": by_gen,
    }


def bench_roofline(
    eng: CollaborativeEngine,
    gen_len: int,
    n_requests: int,
    prompt_len: int,
    batch_size: int,
    arrival_rate: float,
    serve_seed: int = 123,
    num_slots: int | None = None,
) -> dict:
    """Measured-vs-roofline utilization of one traced cached-decode serve.

    The tracer accumulates real wall seconds around every jitted stage
    program (prefill and decode separately) plus the device work shipped;
    joining with the analytic per-stage FLOP/byte counts turns that into a
    per-(stage, phase) utilization against the hardware bound."""
    from repro.obs import SpanTracer, roofline_utilization

    rng = np.random.default_rng(0)
    prompts = [
        rng.integers(0, eng.cfg.vocab_size, size=prompt_len).astype(np.int32)
        for _ in range(n_requests)
    ]
    tracer = SpanTracer()
    eng.rng = np.random.default_rng(serve_seed)
    eng.serve(  # warmup/compile so wall times are steady-state
        prompts,
        arrival_rate=arrival_rate,
        batch_size=batch_size,
        gen_len=gen_len,
        decode_mode="cached",
        num_slots=num_slots,
    )
    eng.rng = np.random.default_rng(serve_seed)
    eng.serve(
        prompts,
        arrival_rate=arrival_rate,
        batch_size=batch_size,
        gen_len=gen_len,
        decode_mode="cached",
        num_slots=num_slots,
        tracer=tracer,
    )
    rows = roofline_utilization(tracer, eng.cfg)
    for key, r in rows.items():
        print(
            f"roofline {key:18s}: wall {r['measured_wall_s']*1e3:8.2f}ms  "
            f"bound {r['bound_s']*1e6:8.2f}us  util {r['utilization']:.2e}  "
            f"calls {r['calls']:4d}  padded {r['padded_row_frac']*100:4.1f}%"
        )
    return {
        "workload": {
            "n_requests": n_requests,
            "prompt_len": prompt_len,
            "gen_len": gen_len,
            "batch_size": batch_size,
        },
        "by_stage_phase": rows,
    }


def _kv_token_bytes(cfg, max_len: int) -> list[int]:
    """Per-stage bytes of sequence-dim (pageable) cache leaves per token of
    capacity (stages may hold different period counts)."""
    per_stage = []
    for stage_idx in range(1, cfg.num_stages + 1):
        dense = model_lib.init_stage_slot_caches(cfg, stage_idx, 1, max_len)
        total = 0
        for period in dense:
            for key, leaf in period.items():
                if key in model_lib.PAGED_CACHE_LEAVES:
                    total += leaf.nbytes
        per_stage.append(total // max_len)
    return per_stage


def _paged_prompts(rng, vocab: int, n_groups: int, group: int, n_long: int):
    """Production-shaped mix: groups of short requests sharing a 48-token
    prompt prefix (system-prompt style) plus a few long-context requests.
    Short rows waste most of a dense ``max_len`` arena — the memory the
    paged layout reclaims."""
    prompts = []
    for _ in range(n_groups):
        common = rng.integers(0, vocab, size=48).astype(np.int32)
        for _ in range(group):
            own = rng.integers(0, vocab, size=int(rng.integers(8, 24)))
            prompts.append(np.concatenate([common, own.astype(np.int32)]))
    for _ in range(n_long):
        prompts.append(rng.integers(0, vocab, size=384).astype(np.int32))
    return prompts


def bench_paged(
    eng: CollaborativeEngine,
    gen_len: int,
    block_size: int,
    dense_slots: int,
    arrival_rate: float,
    serve_seed: int = 123,
    n_groups: int = 4,
    group: int = 4,
    n_long: int = 4,
) -> dict:
    rng = np.random.default_rng(0)
    prompts = _paged_prompts(rng, eng.cfg.vocab_size, n_groups, group, n_long)
    max_len = max(int(p.shape[0]) for p in prompts) + gen_len
    # equal KV bytes: the paged pool gets the dense arenas' token capacity
    # (dense_slots * max_len tokens), rounded DOWN to block granularity so
    # the paged run never holds more KV memory; slot rings are bookkeeping
    # rows (pos only for attention configs), so the paged run may hold many
    # more sequences in the same KV memory
    num_blocks = (dense_slots * max_len) // block_size
    paged_slots = 8 * dense_slots

    reference = {}
    for i, p in enumerate(prompts):
        toks, stage = monolithic_generate(
            eng.programs.params, eng.cfg, p, eng.thresholds, gen_len
        )
        reference[i] = (stage, tuple(toks))

    runs: dict[str, dict] = {}
    seqs: dict[str, dict] = {}
    for layout in ("dense", "paged"):
        kw = dict(
            arrival_rate=arrival_rate,
            batch_size=dense_slots,
            gen_len=gen_len,
            decode_mode="cached",
        )
        if layout == "dense":
            kw["num_slots"] = dense_slots
        else:
            kw.update(
                cache_layout="paged",
                block_size=block_size,
                num_slots=paged_slots,
                num_blocks=num_blocks,
            )
        eng.rng = np.random.default_rng(serve_seed)
        eng.serve(prompts, **kw)  # warmup/compile
        eng.rng = np.random.default_rng(serve_seed)
        t0 = time.perf_counter()
        stats = eng.serve(prompts, **kw)
        wall = time.perf_counter() - t0
        s = stats.summary()
        seqs[layout] = stats.sequences_by_rid()
        runs[layout] = {
            "wall_s": wall,
            "tokens_per_s": s["generated_tokens"] / wall,
            "generated_tokens": s["generated_tokens"],
            "num_completed": s["num_completed"],
            "peak_in_flight": s["peak_in_flight"],
            "mean_delay_s": s["mean_delay"],
            "exit_histogram": s["exit_histogram"],
            "kv_token_capacity_per_replica": (
                dense_slots * max_len if layout == "dense" else num_blocks * block_size
            ),
            "prefix_hit_rate": s["prefix_hit_rate"],
            "prefix_hit_blocks": s["prefix_hit_blocks"],
            "prefix_total_blocks": s["prefix_total_blocks"],
            "block_occupancy_mean": s["block_occupancy_mean"],
            "block_occupancy_peak": s["block_occupancy_peak"],
        }
        print(
            f"{layout:5s}: peak_in_flight {s['peak_in_flight']:3d}  "
            f"tok/s {runs[layout]['tokens_per_s']:8.1f}  "
            f"prefix_hits {s['prefix_hit_rate']*100:4.1f}%  "
            f"occupancy peak {s['block_occupancy_peak']*100 if layout == 'paged' else float('nan'):5.1f}%"
        )
    identical = seqs["dense"] == seqs["paged"] == reference
    token_bytes = _kv_token_bytes(eng.cfg, max_len)
    inflight_gain = runs["paged"]["peak_in_flight"] / max(
        runs["dense"]["peak_in_flight"], 1
    )
    print(
        f"token-identical (paged == dense == monolithic): {identical}  "
        f"in-flight gain at equal KV bytes: {inflight_gain:.2f}x"
    )
    return {
        "workload": {
            "n_requests": len(prompts),
            "prompt_lens": sorted(int(p.shape[0]) for p in prompts),
            "gen_len": gen_len,
            "block_size": block_size,
            "dense_slots": dense_slots,
            "paged_slots": paged_slots,
            "num_blocks_per_replica": num_blocks,
            "max_len": max_len,
            "kv_bytes_per_token_by_stage": token_bytes,
            "kv_bytes_per_replica_by_stage": [
                b * dense_slots * max_len for b in token_bytes
            ],
            "arrival_rate": arrival_rate,
            "threshold": float(eng.thresholds[0]),
        },
        "by_layout": runs,
        "tokens_identical": identical,
        "in_flight_gain_at_equal_kv_bytes": inflight_gain,
    }


def validate_paged_schema(payload: dict) -> None:
    """The contract the paged capacity bench is held to."""
    assert "paged" in payload and "meta" in payload
    res = payload["paged"]
    assert res["tokens_identical"] is True, (
        "paged decode diverged from the dense layout / monolithic reference"
    )
    dense, paged = res["by_layout"]["dense"], res["by_layout"]["paged"]
    assert (
        paged["kv_token_capacity_per_replica"]
        <= dense["kv_token_capacity_per_replica"]
    ), "paged run used MORE KV memory than dense"
    assert res["in_flight_gain_at_equal_kv_bytes"] >= 2.0, (
        f"paged layout sustained only "
        f"{res['in_flight_gain_at_equal_kv_bytes']:.2f}x the dense in-flight "
        "requests at equal KV bytes (need >= 2x)"
    )
    assert paged["prefix_hit_blocks"] > 0
    assert 0.0 < paged["block_occupancy_peak"] <= 1.0


def validate_schema(payload: dict) -> None:
    """The contract ``bench-smoke`` (CI) holds this benchmark to."""
    assert "decode" in payload and "meta" in payload
    dec = payload["decode"]
    for key in ("workload", "by_gen_len"):
        assert key in dec, f"missing {key}"
    for gen_len, entry in dec["by_gen_len"].items():
        assert entry["tokens_identical"] is True, (
            f"gen_len {gen_len}: cached decode diverged from the stateless "
            "baseline / monolithic reference"
        )
        assert entry["speedup_cached_vs_stateless"] > 0
        for mode in ("cached", "stateless"):
            m = entry["by_mode"][mode]
            for field in ("wall_s", "tokens_per_s", "generated_tokens", "num_batches"):
                assert np.isfinite(m[field]), f"{mode}.{field} not finite"
    roof = payload["roofline"]["by_stage_phase"]
    assert roof, "roofline join produced no (stage, phase) rows"
    phases = {r["phase"] for r in roof.values()}
    assert "prefill" in phases and "decode" in phases, (
        f"roofline missing a phase: saw {sorted(phases)}"
    )
    for key, r in roof.items():
        assert r["measured_wall_s"] > 0, f"{key}: no measured wall time"
        assert r["bound_s"] > 0 and np.isfinite(r["utilization"]), (
            f"{key}: degenerate roofline bound"
        )
        assert r["calls"] > 0 and r["device_tokens"] > 0


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="BENCH_decode.json")
    # decode-dominated workload: long prompts make the stateless baseline's
    # O(prefix) re-compute per token visible against per-dispatch overhead
    ap.add_argument("--n-requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=384)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--num-slots", type=int, default=8)
    ap.add_argument("--gen-lens", type=int, nargs="+", default=[8, 32])
    ap.add_argument("--repeats", type=int, default=2)
    ap.add_argument(
        "--arrival-rate",
        type=float,
        default=1e6,
        help="Poisson arrival rate; high = closed-loop (all requests queued)",
    )
    ap.add_argument(
        "--cache-layout",
        choices=("dense", "paged"),
        default="dense",
        help="dense: cached-vs-stateless throughput (BENCH_decode.json); "
        "paged: paged-vs-dense capacity at equal KV bytes (BENCH_paged.json)",
    )
    ap.add_argument(
        "--block-size",
        type=int,
        default=16,
        help="tokens per KV block for --cache-layout paged",
    )
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="tiny workload; validate the JSON schema and exit nonzero on drift",
    )
    args = ap.parse_args()
    meta = {
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "platform": platform.platform(),
    }

    if args.cache_layout == "paged":
        if args.out == "BENCH_decode.json":
            args.out = "BENCH_paged.json"
        gen_len = 8 if args.smoke else 32
        dense_slots = 2 if args.smoke else 4
        groups = dict(n_groups=3, group=4, n_long=2) if args.smoke else {}
        eng = build_engine(threshold=0.35)
        res = bench_paged(
            eng,
            gen_len=gen_len,
            block_size=args.block_size,
            dense_slots=dense_slots,
            arrival_rate=args.arrival_rate,
            **groups,
        )
        payload = {"paged": res, "meta": meta}
        validate_paged_schema(payload)
        with open(args.out, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {args.out}")
        return

    if args.smoke:
        args.n_requests, args.prompt_len, args.gen_lens = 6, 8, [4]
        args.batch_size, args.num_slots, args.repeats = 4, 4, 1

    eng = build_engine(threshold=0.35)
    res = bench_decode(
        eng,
        tuple(args.gen_lens),
        args.n_requests,
        args.prompt_len,
        args.batch_size,
        args.arrival_rate,
        repeats=args.repeats,
        num_slots=args.num_slots,
    )
    roofline_res = bench_roofline(
        eng,
        gen_len=max(args.gen_lens),
        n_requests=args.n_requests,
        prompt_len=args.prompt_len,
        batch_size=args.batch_size,
        arrival_rate=args.arrival_rate,
        num_slots=args.num_slots,
    )
    payload = {"decode": res, "roofline": roofline_res, "meta": meta}
    validate_schema(payload)
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
