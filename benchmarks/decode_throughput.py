"""Decode throughput: cache-threaded decode vs stateless re-prefill.

Runs ``CollaborativeEngine.serve`` at gen_len in {8, 32} in both decode
modes on one fixed workload (same prompts, same arrival process, same
thresholds), asserts token-identical sequences and exit decisions between
the modes AND against the monolithic ``model.prefill`` + ``model.decode_step``
reference, and measures wall-clock decode tokens/s.  The cached mode does
O(1) work per token per stage; the stateless baseline recomputes the full
prefix at every stage on every step — the waste this PR removes.  Results
land in ``BENCH_decode.json``.

    PYTHONPATH=src python benchmarks/decode_throughput.py [--out BENCH_decode.json]
    PYTHONPATH=src python benchmarks/decode_throughput.py --smoke   # CI schema check
"""
from __future__ import annotations

import argparse
import json
import platform
import time

import numpy as np

import jax

from repro.configs import get_config
from repro.core.profiles import profile_from_arch
from repro.core.thresholds import synthetic_validation
from repro.core.topology import NetworkSpec, build_edge_network
from repro.core.types import DtoHyperParams
from repro.models import model as model_lib
from repro.serving import CollaborativeEngine, monolithic_generate


def build_engine(seed: int = 0, threshold: float | None = 0.1) -> CollaborativeEngine:
    """A small-but-real staged model: per-dispatch overhead vs per-row compute
    at a ratio representative of a serving host driving an accelerator."""
    cfg = get_config("stablelm-1.6b").reduced(
        vocab_size=128,
        d_model=64,
        d_ff=128,
        num_heads=2,
        num_kv_heads=2,
        head_dim=32,
    )
    params = model_lib.init_params(jax.random.key(0), cfg)
    profile = profile_from_arch(cfg)
    topo = build_edge_network(
        seed=seed, profile=profile, spec=NetworkSpec(num_eds=4, es_per_stage=(2, 2))
    )
    ep = synthetic_validation(seed=1, profile=profile)
    eng = CollaborativeEngine(
        params, cfg, topo, profile, ep, DtoHyperParams(rounds=20), seed=seed
    )
    eng.configuration_phase()
    if threshold is not None:
        # a mid-range threshold so the workload mixes early exits (rows
        # retiring mid-batch) with full-length generations
        eng.state.thresholds = np.full_like(eng.state.thresholds, threshold)
    return eng


def bench_decode(
    eng: CollaborativeEngine,
    gen_lens: tuple[int, ...],
    n_requests: int,
    prompt_len: int,
    batch_size: int,
    arrival_rate: float,
    serve_seed: int = 123,
    repeats: int = 2,
    num_slots: int | None = None,
) -> dict:
    rng = np.random.default_rng(0)
    prompts = [
        rng.integers(0, eng.cfg.vocab_size, size=prompt_len).astype(np.int32)
        for _ in range(n_requests)
    ]
    by_gen: dict[str, dict] = {}
    for gen_len in gen_lens:
        # monolithic single-host reference: the ground truth both engine
        # modes must reproduce token-for-token
        reference = {}
        for i, p in enumerate(prompts):
            toks, stage = monolithic_generate(
                eng.programs.params, eng.cfg, p, eng.thresholds, gen_len
            )
            reference[i] = (stage, tuple(toks))
        modes: dict[str, dict] = {}
        seqs: dict[str, dict] = {}
        for mode in ("stateless", "cached"):
            eng.rng = np.random.default_rng(serve_seed)
            eng.serve(
                prompts,
                arrival_rate=arrival_rate,
                batch_size=batch_size,
                gen_len=gen_len,
                decode_mode=mode,
                num_slots=num_slots,
            )  # warmup/compile
            walls = []
            for _ in range(repeats):
                eng.rng = np.random.default_rng(serve_seed)
                t0 = time.perf_counter()
                stats = eng.serve(
                    prompts,
                    arrival_rate=arrival_rate,
                    batch_size=batch_size,
                    gen_len=gen_len,
                    decode_mode=mode,
                    num_slots=num_slots,
                )
                walls.append(time.perf_counter() - t0)
            wall = float(np.median(walls))
            s = stats.summary()
            seqs[mode] = stats.sequences_by_rid()
            modes[mode] = {
                "wall_s": wall,
                "tokens_per_s": s["generated_tokens"] / wall,
                "generated_tokens": s["generated_tokens"],
                "num_completed": s["num_completed"],
                "mean_delay_s": s["mean_delay"],
                "p95_delay_s": s["p95_delay"],
                "num_batches": s["num_batches"],
                "padded_row_frac": s["padded_row_frac"],
                "exit_histogram": s["exit_histogram"],
            }
            print(
                f"gen_len {gen_len:3d} {mode:9s}: "
                f"{modes[mode]['tokens_per_s']:8.1f} tok/s  wall {wall:.3f}s  "
                f"batches {s['num_batches']:5d}  exits {s['exit_histogram']}"
            )
        identical = (
            seqs["cached"] == seqs["stateless"] == reference
        )
        speedup = modes["cached"]["tokens_per_s"] / modes["stateless"]["tokens_per_s"]
        print(
            f"gen_len {gen_len:3d}: token-identical (cached == stateless == "
            f"monolithic): {identical}  speedup {speedup:.2f}x"
        )
        by_gen[str(gen_len)] = {
            "by_mode": modes,
            "tokens_identical": identical,
            "speedup_cached_vs_stateless": speedup,
        }
    return {
        "workload": {
            "n_requests": n_requests,
            "prompt_len": prompt_len,
            "batch_size": batch_size,
            "num_slots": num_slots,
            "arrival_rate": arrival_rate,
            "threshold": float(eng.thresholds[0]),
        },
        "by_gen_len": by_gen,
    }


def validate_schema(payload: dict) -> None:
    """The contract ``bench-smoke`` (CI) holds this benchmark to."""
    assert "decode" in payload and "meta" in payload
    dec = payload["decode"]
    for key in ("workload", "by_gen_len"):
        assert key in dec, f"missing {key}"
    for gen_len, entry in dec["by_gen_len"].items():
        assert entry["tokens_identical"] is True, (
            f"gen_len {gen_len}: cached decode diverged from the stateless "
            "baseline / monolithic reference"
        )
        assert entry["speedup_cached_vs_stateless"] > 0
        for mode in ("cached", "stateless"):
            m = entry["by_mode"][mode]
            for field in ("wall_s", "tokens_per_s", "generated_tokens", "num_batches"):
                assert np.isfinite(m[field]), f"{mode}.{field} not finite"


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="BENCH_decode.json")
    # decode-dominated workload: long prompts make the stateless baseline's
    # O(prefix) re-compute per token visible against per-dispatch overhead
    ap.add_argument("--n-requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=384)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--num-slots", type=int, default=8)
    ap.add_argument("--gen-lens", type=int, nargs="+", default=[8, 32])
    ap.add_argument("--repeats", type=int, default=2)
    ap.add_argument(
        "--arrival-rate",
        type=float,
        default=1e6,
        help="Poisson arrival rate; high = closed-loop (all requests queued)",
    )
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="tiny workload; validate the JSON schema and exit nonzero on drift",
    )
    args = ap.parse_args()
    if args.smoke:
        args.n_requests, args.prompt_len, args.gen_lens = 6, 8, [4]
        args.batch_size, args.num_slots, args.repeats = 4, 4, 1

    eng = build_engine(threshold=0.35)
    res = bench_decode(
        eng,
        tuple(args.gen_lens),
        args.n_requests,
        args.prompt_len,
        args.batch_size,
        args.arrival_rate,
        repeats=args.repeats,
        num_slots=args.num_slots,
    )
    payload = {
        "decode": res,
        "meta": {
            "jax": jax.__version__,
            "backend": jax.default_backend(),
            "platform": platform.platform(),
        },
    }
    validate_schema(payload)
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
