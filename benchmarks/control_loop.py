"""Closed-loop DTO-EE vs static-once configuration over the LIVE engine.

The paper's Figs. 7–8 claim: in a dynamic environment, re-optimizing the
offloading strategy and thresholds every slot beats a one-shot decision.
This benchmark runs that experiment against the REAL serving data plane:

  * per scenario (arrival burst / node slowdown / link degradation / node
    failure), the same Poisson workload is served twice — once with the
    pre-serve DTO-EE configuration frozen (``static``), once with telemetry
    + a ReconfigController re-optimizing mid-serve (``closed``) — and mean
    delay, delay stddev, p95, and branch-accuracy-weighted expected accuracy
    are compared;
  * a traced serve under the static configuration reports tail latency
    (p50/p95/p99) and the measured queue/compute/comms delay attribution
    against the DTO-EE model terms per node (span sums must reconcile with
    reported delays exactly);
  * the threshold-aware batch policy is A/B'd against FIFO on a cached
    decode workload (padded-row waste, token-identical outputs);
  * the simulator's same-timestamp event harvest is measured before/after
    (tasks/s; results asserted identical).

Results land in ``BENCH_control.json``; ``--smoke`` shrinks everything and
keeps only the structural assertions (CI runs it via ``make bench-smoke``).

    PYTHONPATH=src python benchmarks/control_loop.py [--out BENCH_control.json]
"""
from __future__ import annotations

import argparse
import json
import platform
import time

import numpy as np

import jax

from repro.configs import get_config
from repro.control import (
    ControllerConfig,
    ReconfigController,
    Telemetry,
    TelemetryConfig,
    get_scenario,
)
from repro.core import dto_ee, simulator
from repro.core.profiles import profile_from_arch
from repro.core.thresholds import synthetic_validation
from repro.core.topology import NetworkSpec, build_edge_network
from repro.core.types import DtoHyperParams, RESNET101_PROFILE
from repro.models import model as model_lib
from repro.serving import CollaborativeEngine

SCENARIOS = ("burst", "slowdown", "link", "failure")
# acceptance: the closed loop must beat static on mean AND stddev here
MUST_WIN = ("burst", "slowdown", "failure")


def _cfg():
    return get_config("stablelm-1.6b").reduced(
        vocab_size=128,
        d_model=64,
        d_ff=128,
        num_heads=2,
        num_kv_heads=2,
        head_dim=32,
    )


def build_engine(params, cfg, topo, profile, ep, threshold: float, seed: int = 0):
    """Fresh engine + one converged-enough pre-serve configuration phase —
    the shared starting point of both policies."""
    eng = CollaborativeEngine(
        params, cfg, topo, profile, ep, DtoHyperParams(rounds=20), seed=seed
    )
    eng.configuration_phase()
    # live confidences of the reduced model concentrate low; pin the
    # thresholds into the sensitive range so the workload mixes exits
    eng.state.thresholds = np.full_like(eng.state.thresholds, threshold)
    return eng


def expected_accuracy(profile, exit_hist: dict) -> float:
    """Branch-accuracy-weighted accuracy of a realized exit histogram (the
    engine has no labels; the profile's per-branch accuracies stand in)."""
    total = sum(exit_hist.values())
    if total == 0:
        return float("nan")
    return sum(
        cnt * profile.branch_accuracy[int(stage) - 1]
        for stage, cnt in exit_hist.items()
    ) / total


def bench_closed_loop(
    params, cfg, topo, profile, ep, n_requests: int, rho: float, seed: int,
    rounds: int, threshold: float,
) -> dict:
    rng = np.random.default_rng(seed)
    prompts = [
        rng.integers(0, cfg.vocab_size, size=12).astype(np.int32)
        for _ in range(n_requests)
    ]
    caps = [
        float(sum(topo.mu[v] for v in topo.nodes_at_stage(h))) / profile.alpha[h - 1]
        for h in range(1, profile.num_stages + 1)
    ]
    rate = rho * min(caps)
    span = n_requests / rate

    by_scenario: dict[str, dict] = {}
    for name in SCENARIOS:
        runs: dict[str, dict] = {}
        for policy in ("static", "closed"):
            eng = build_engine(params, cfg, topo, profile, ep, threshold, seed)
            scn = get_scenario(name, eng.topo, p=eng.p, horizon=span, seed=seed)
            tele = Telemetry(eng.topo, TelemetryConfig(window_s=span / 8))
            ctrl = None
            if policy == "closed":
                # adapt_thresholds=False: the controller re-optimizes the
                # OFFLOADING strategy only.  The reduced model's live branch
                # confidences sit far from the synthetic exit profile's, so
                # letting Alg. 3 move thresholds against the synthetic table
                # shifts live exits unpredictably; pinning them also pins
                # accuracy exactly, isolating the routing win.  Calibrating
                # the exit profile from realized (conf, exit) telemetry is
                # recorded as a ROADMAP follow-on.
                ctrl = ReconfigController(
                    tele,
                    ControllerConfig(
                        interval=span / 10,
                        rounds=rounds,
                        drift_deadband=0.08,
                        adapt_thresholds=False,
                    ),
                )
            eng.rng = np.random.default_rng(seed + 7)
            stats = eng.serve(
                prompts,
                arrival_rate=rate,
                batch_size=4,
                gen_len=1,
                scenario=scn,
                controller=ctrl,
                telemetry=tele,
            )
            s = stats.summary()
            runs[policy] = {
                "mean_delay_s": s["mean_delay"],
                "delay_std_s": s["delay_std"],
                "p95_delay_s": s["p95_delay"],
                "num_completed": s["num_completed"],
                "num_reconfigs": s["num_reconfigs"],
                "resubmitted": s["resubmitted"],
                "exit_histogram": s["exit_histogram"],
                "expected_accuracy": expected_accuracy(
                    profile, s["exit_histogram"]
                ),
                "padded_row_frac": s["padded_row_frac"],
            }
            print(
                f"{name:9s} {policy:7s} mean {s['mean_delay']:.3f}s  "
                f"std {s['delay_std']:.3f}s  p95 {s['p95_delay']:.3f}s  "
                f"reconfigs {s['num_reconfigs']:2d}  "
                f"acc {runs[policy]['expected_accuracy']:.4f}"
            )
        st, cl = runs["static"], runs["closed"]
        by_scenario[name] = {
            "by_policy": runs,
            "mean_delay_improvement": st["mean_delay_s"] / cl["mean_delay_s"],
            "delay_std_improvement": st["delay_std_s"] / cl["delay_std_s"],
            "accuracy_delta": cl["expected_accuracy"] - st["expected_accuracy"],
        }
        print(
            f"{name:9s} closed/static: mean {by_scenario[name]['mean_delay_improvement']:.2f}x  "
            f"std {by_scenario[name]['delay_std_improvement']:.2f}x  "
            f"d_acc {by_scenario[name]['accuracy_delta']:+.4f}"
        )
    return {
        "workload": {
            "n_requests": n_requests,
            "arrival_rate": rate,
            "utilization": rho,
            "span_s": span,
            "threshold": threshold,
            "controller_rounds": rounds,
            "stage_capacities_tasks_per_s": caps,
        },
        "by_scenario": by_scenario,
    }


def bench_attribution(
    params, cfg, topo, profile, ep, n_requests: int, rho: float, seed: int,
    threshold: float,
) -> dict:
    """Traced serve under the static configuration: tail latency + measured
    vs DTO-EE-model delay attribution (the gate that the model the optimizer
    minimizes still describes the live engine)."""
    from repro.core.queueing import node_remaining_ratio
    from repro.obs import MetricsCollector, SpanTracer, attribution_report

    rng = np.random.default_rng(seed)
    prompts = [
        rng.integers(0, cfg.vocab_size, size=12).astype(np.int32)
        for _ in range(n_requests)
    ]
    caps = [
        float(sum(topo.mu[v] for v in topo.nodes_at_stage(h))) / profile.alpha[h - 1]
        for h in range(1, profile.num_stages + 1)
    ]
    rate = rho * min(caps)
    eng = build_engine(params, cfg, topo, profile, ep, threshold, seed)
    tracer, metrics = SpanTracer(), MetricsCollector()
    eng.rng = np.random.default_rng(seed + 7)
    stats = eng.serve(
        prompts,
        arrival_rate=rate,
        batch_size=4,
        gen_len=1,
        tracer=tracer,
        metrics=metrics,
    )
    s = stats.summary()
    # the same I_node the optimizer saw: remaining ratios under the live
    # thresholds broadcast onto nodes
    I_node = np.asarray(
        node_remaining_ratio(
            eng.topo,
            np.asarray(ep.evaluate(eng.thresholds).stage_remaining, np.float32),
        )
    )
    rep = attribution_report(
        tracer, eng.p, eng.topo, profile, I_node, stats
    )
    out = {
        "workload": {
            "n_requests": n_requests,
            "arrival_rate": rate,
            "utilization": rho,
            "threshold": threshold,
        },
        "tail_latency_s": {
            "p50": s["p50_delay"],
            "p95": s["p95_delay"],
            "p99": s["p99_delay"],
            "mean": s["mean_delay"],
        },
        "delay_components_s": s["delay_components"],
        "per_stage_components": s["per_stage_components"],
        "attribution": rep,
    }
    mc = rep["measured"]
    md = rep["model"]
    print(
        f"attribution: p50 {s['p50_delay']*1e3:.1f}ms  "
        f"p95 {s['p95_delay']*1e3:.1f}ms  p99 {s['p99_delay']*1e3:.1f}ms  "
        f"reconciles {rep['reconciles']} "
        f"(max residual {rep['max_residual_s']:.2e}s)"
    )
    print(
        f"  measured queue/compute/comms: "
        f"{mc['queue_s']*1e3:.2f}/{mc['compute_s']*1e3:.2f}/"
        f"{mc['comms_s']*1e3:.2f} ms   model: "
        f"{md['queue_s']*1e3:.2f}/{md['compute_s']*1e3:.2f}/"
        f"{md['comms_s']*1e3:.2f} ms"
    )
    for j, e in sorted(rep["per_node"].items()):
        if e["visits"]:
            print(
                f"  node {j}: sojourn measured {e['measured_sojourn_s']*1e3:7.2f}ms  "
                f"model {e['model_sojourn_s']*1e3:7.2f}ms  "
                f"rel_err {e.get('rel_error', float('nan')):+.2f}  "
                f"visits {e['visits']}"
            )
    return out


def bench_packing(
    params, cfg, topo, profile, ep, n_requests: int, gen_len: int, seed: int,
    threshold: float = 0.1,
) -> dict:
    """Threshold-aware packing vs FIFO at closed-loop load (all queued)."""
    rng = np.random.default_rng(seed)
    prompts = [
        rng.integers(0, cfg.vocab_size, size=int(rng.integers(8, 24))).astype(
            np.int32
        )
        for _ in range(n_requests)
    ]
    runs: dict[str, dict] = {}
    seqs: dict[str, dict] = {}
    for policy in ("fifo", "threshold"):
        eng = build_engine(params, cfg, topo, profile, ep, threshold, seed)
        eng.rng = np.random.default_rng(seed + 11)
        stats = eng.serve(
            prompts,
            arrival_rate=1e6,
            batch_size=8,
            gen_len=gen_len,
            decode_mode="cached",
            num_slots=8,
            batch_policy=policy,
        )
        s = stats.summary()
        seqs[policy] = stats.sequences_by_rid()
        runs[policy] = {
            "padded_row_frac": s["padded_row_frac"],
            "num_forward_rows": s["num_forward_rows"],
            "num_real_rows": s["num_real_rows"],
            "num_batches": s["num_batches"],
            "mean_delay_s": s["mean_delay"],
            "exit_histogram": s["exit_histogram"],
        }
        print(
            f"packing {policy:9s}: padded {s['padded_row_frac']*100:.2f}%  "
            f"rows {s['num_real_rows']}/{s['num_forward_rows']}  "
            f"batches {s['num_batches']}"
        )
    identical = seqs["fifo"] == seqs["threshold"]
    print(
        f"packing token-identical: {identical}  waste "
        f"{runs['fifo']['padded_row_frac']*100:.2f}% -> "
        f"{runs['threshold']['padded_row_frac']*100:.2f}%"
    )
    return {
        "workload": {
            "n_requests": n_requests,
            "gen_len": gen_len,
            "batch_size": 8,
            "threshold": threshold,
        },
        "by_policy": runs,
        "tokens_identical": identical,
    }


def bench_simulator(duration: float, arrival_scale: float, repeats: int) -> dict:
    """Same-timestamp event harvest: before/after tasks/s (satellite of the
    1e6 tasks/slot roadmap item; results must be identical)."""
    profile = RESNET101_PROFILE
    topo = build_edge_network(seed=0, profile=profile, arrival_rate_scale=arrival_scale)
    ep = synthetic_validation(seed=1, profile=profile)
    res = dto_ee.run_configuration_phase(topo, profile, ep, DtoHyperParams(rounds=30))
    p, thr = np.asarray(res.state.carry.p), res.state.thresholds
    out: dict[str, dict] = {}
    results = {}
    for label, coalesce in (("before", False), ("after", True)):
        walls = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            sim = simulator.simulate_slot(
                topo, profile, ep, p, thr, duration=duration, seed=3,
                coalesce=coalesce,
            )
            walls.append(time.perf_counter() - t0)
        wall = float(np.min(walls))
        results[label] = sim
        out[label] = {
            "tasks": sim.generated,
            "wall_s": wall,
            "tasks_per_s": sim.generated / wall,
        }
        print(
            f"simulator {label} (coalesce={coalesce}): "
            f"{out[label]['tasks_per_s']:,.0f} tasks/s ({sim.generated} tasks)"
        )
    a, b = results["before"], results["after"]
    identical = (
        a.mean_delay == b.mean_delay
        and a.completed == b.completed
        and np.array_equal(a.exit_fraction, b.exit_fraction)
    )
    print(f"simulator results identical: {identical}")
    return {
        "coalesce": out,
        "results_identical": identical,
        "speedup": out["after"]["tasks_per_s"] / out["before"]["tasks_per_s"],
    }


def validate_schema(payload: dict, smoke: bool) -> None:
    """The contract this benchmark (and ``bench-smoke``) is held to."""
    assert (
        "control" in payload
        and "attribution" in payload
        and "packing" in payload
        and "simulator" in payload
    )
    ctl = payload["control"]["by_scenario"]
    for name in SCENARIOS:
        for policy in ("static", "closed"):
            run = ctl[name]["by_policy"][policy]
            assert run["num_completed"] > 0
            assert np.isfinite(run["mean_delay_s"])
        assert ctl[name]["by_policy"]["closed"]["num_reconfigs"] > 0, (
            f"{name}: the closed loop never reconfigured"
        )
        assert abs(ctl[name]["accuracy_delta"]) <= 0.01, (
            f"{name}: closed-loop accuracy drifted "
            f"{ctl[name]['accuracy_delta']:+.4f} (> 1 point) from static"
        )
    at = payload["attribution"]
    assert at["attribution"]["reconciles"] is True, (
        "span component sums do not reconcile with reported delays "
        f"(max residual {at['attribution']['max_residual_s']:.2e}s)"
    )
    assert (
        at["tail_latency_s"]["p50"]
        <= at["tail_latency_s"]["p95"]
        <= at["tail_latency_s"]["p99"]
    )
    assert at["attribution"]["per_node"], "attribution covered no ES node"
    for comp in ("queue_s", "compute_s", "comms_s", "total_s"):
        assert np.isfinite(at["attribution"]["measured"][comp])
        assert np.isfinite(at["attribution"]["model"][comp])
    pk = payload["packing"]
    assert pk["tokens_identical"] is True, (
        "threshold-aware packing changed emitted tokens"
    )
    assert (
        pk["by_policy"]["threshold"]["padded_row_frac"]
        <= pk["by_policy"]["fifo"]["padded_row_frac"]
    ), "threshold packing increased padded-row waste"
    assert payload["simulator"]["results_identical"] is True
    if smoke:
        return
    # full-size acceptance: closed loop beats static on mean AND stddev
    # under the burst / slowdown / failure scenarios, and packing strictly
    # reduces waste
    for name in MUST_WIN:
        assert ctl[name]["mean_delay_improvement"] > 1.0, (
            f"{name}: closed loop did not improve mean delay "
            f"({ctl[name]['mean_delay_improvement']:.3f}x)"
        )
        assert ctl[name]["delay_std_improvement"] > 1.0, (
            f"{name}: closed loop did not improve delay stddev "
            f"({ctl[name]['delay_std_improvement']:.3f}x)"
        )
    assert (
        pk["by_policy"]["threshold"]["padded_row_frac"]
        < pk["by_policy"]["fifo"]["padded_row_frac"]
    ), "threshold packing did not strictly reduce padded-row waste"


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="BENCH_control.json")
    ap.add_argument("--n-requests", type=int, default=96)
    ap.add_argument(
        "--rho",
        type=float,
        default=0.55,
        help="offered load as a fraction of the bottleneck stage capacity",
    )
    ap.add_argument("--controller-rounds", type=int, default=15)
    ap.add_argument(
        "--threshold",
        type=float,
        default=0.12,
        help="initial exit thresholds (sensitive range of the reduced model)",
    )
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="tiny workload; validate schema + invariants, skip win gates",
    )
    args = ap.parse_args()

    if args.smoke:
        args.n_requests = 32
        args.controller_rounds = 8
    sim_kw = (
        dict(duration=0.6, arrival_scale=10.0, repeats=2)
        if args.smoke
        else dict(duration=3.0, arrival_scale=20.0, repeats=3)
    )
    pack_n, pack_gen = (16, 6) if args.smoke else (32, 12)

    cfg = _cfg()
    params = model_lib.init_params(jax.random.key(0), cfg)
    profile = profile_from_arch(cfg)
    # capacity_scale drops Jetson-class service times into the ~10-50 ms
    # band, so slots, decision times (rounds x 2 ms), and telemetry windows
    # sit at the paper's timescale relative to each other
    topo = build_edge_network(
        seed=args.seed,
        profile=profile,
        spec=NetworkSpec(num_eds=4, es_per_stage=(2, 3)),
        capacity_scale=0.005,
    )
    ep = synthetic_validation(seed=args.seed + 1, profile=profile)

    payload = {
        "control": bench_closed_loop(
            params, cfg, topo, profile, ep, args.n_requests, args.rho,
            args.seed, args.controller_rounds, args.threshold,
        ),
        "attribution": bench_attribution(
            params, cfg, topo, profile, ep, args.n_requests, args.rho,
            args.seed, args.threshold,
        ),
        "packing": bench_packing(
            params, cfg, topo, profile, ep, pack_n, pack_gen, args.seed
        ),
        "simulator": bench_simulator(**sim_kw),
        "meta": {
            "jax": jax.__version__,
            "backend": jax.default_backend(),
            "platform": platform.platform(),
            "smoke": args.smoke,
        },
    }
    validate_schema(payload, smoke=args.smoke)
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
