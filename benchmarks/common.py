"""Shared benchmark harness: run every algorithm on a scenario and measure
delay/accuracy in the discrete-event simulator (the paper's methodology).

Decision-time model (paper §4.1: 100 ms configuration phase, 2 ms local
communication):

  DTO-EE : rounds x 2 ms              (all nodes update concurrently)
  CF/BF  : 2 ms                       (one local exchange)
  NGTO   : sweeps x offloaders x 2 ms (round-robin serialization — its
                                       documented weakness)
  GA     : 2 x H x 2 ms collection + stale lambda snapshot (outdated info)

During a slot's first ``decision_time`` seconds, routing still follows the
PREVIOUS slot's strategy (simulator.strategy_switch) — this is what makes
the dynamic environment hurt slow deciders.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import baselines, dto_ee, simulator
from repro.core.thresholds import ExitProfile
from repro.core.types import DtoHyperParams, ModelProfile, Topology

LOCAL_COMM_S = 0.002

ALGOS = ("DTO-EE", "CF", "BF", "NGTO", "GA")


@dataclasses.dataclass
class AlgoState:
    """Cross-slot warm state for one algorithm."""

    p: np.ndarray
    thresholds: np.ndarray
    decision_time: float
    dto_state: object | None = None  # DTO-EE's RoundCarry etc.
    lam_snapshot: np.ndarray | None = None  # GA's (stale) load view


def decide(
    algo: str,
    topo: Topology,
    profile: ModelProfile,
    exit_profile: ExitProfile,
    hyper: DtoHyperParams,
    prev: AlgoState | None,
    adapt_thresholds: bool = True,
    static: bool = False,
) -> AlgoState:
    """One configuration-update phase for ``algo`` (warm-started from prev).

    ``static=True`` models a stationary environment measured at steady state
    (the paper's Figs. 3-6): DTO-EE runs configuration phases to convergence
    (consecutive slots of an unchanged environment, warm-started), matching
    NGTO's run-to-Nash-equilibrium semantics.  Dynamic experiments use one
    phase per slot."""
    thr0 = prev.thresholds if prev is not None else np.full(
        exit_profile.num_early_branches, 0.8
    )
    if algo == "DTO-EE":
        if static:
            res = dto_ee.solve(
                topo,
                profile,
                exit_profile,
                hyper,
                adapt_thresholds=adapt_thresholds,
            )
        else:
            state = None
            if prev is not None and prev.dto_state is not None:
                state = dataclasses.replace(prev.dto_state)
            res = dto_ee.run_configuration_phase(
                topo,
                profile,
                exit_profile,
                hyper,
                state=state,
                adapt_thresholds=adapt_thresholds,
            )
        return AlgoState(
            p=np.asarray(res.state.carry.p),
            thresholds=res.state.thresholds,
            decision_time=hyper.rounds * LOCAL_COMM_S,
            dto_state=res.state,
        )

    ev0 = exit_profile.evaluate(thr0)
    if algo == "CF":
        p = np.asarray(baselines.computing_first(topo))
        dt = LOCAL_COMM_S
    elif algo == "BF":
        p = np.asarray(baselines.bandwidth_first(topo))
        dt = LOCAL_COMM_S
    elif algo == "NGTO":
        p_j, sweeps = baselines.ngto(topo, profile, ev0.stage_remaining)
        p = np.asarray(p_j)
        n_off = int(np.sum(topo.node_stage < topo.num_stages))
        dt = sweeps * n_off * LOCAL_COMM_S
    elif algo == "GA":
        lam_snap = prev.lam_snapshot if prev is not None else None
        ga = baselines.genetic_paths(
            topo, profile, ev0.stage_remaining, lam_snapshot=lam_snap, seed=11
        )
        p = np.asarray(ga.p)
        dt = 2 * topo.num_stages * LOCAL_COMM_S
    else:
        raise ValueError(algo)

    if adapt_thresholds:
        thr, _, _ = baselines.adapt_thresholds_for_strategy(
            topo, profile, exit_profile, p, hyper, thresholds0=thr0, sweeps=3
        )
    else:
        thr = thr0
    # GA's next slot sees THIS slot's loads (one slot stale)
    import jax.numpy as jnp

    from repro.core import queueing

    I_node = jnp.asarray(exit_profile.evaluate(thr).stage_remaining, jnp.float32)[
        jnp.asarray(topo.node_stage)
    ]
    _, lam = queueing.steady_state_flows(p, topo, profile, I_node)
    return AlgoState(
        p=p, thresholds=thr, decision_time=dt, lam_snapshot=np.asarray(lam)
    )


def run_slot(
    topo: Topology,
    profile: ModelProfile,
    exit_profile: ExitProfile,
    state: AlgoState,
    prev: AlgoState | None,
    duration: float = 5.0,
    seed: int = 0,
) -> simulator.SimResult:
    switch = None
    if prev is not None and state.decision_time > 0:
        switch = (min(state.decision_time, duration), prev.p)
    return simulator.simulate_slot(
        topo,
        profile,
        exit_profile,
        state.p,
        state.thresholds,
        duration=duration,
        seed=seed,
        strategy_switch=switch,
    )


def fmt_row(name: str, sim: simulator.SimResult) -> str:
    return (
        f"{name:8s} delay {sim.mean_delay*1e3:7.1f}ms  acc {sim.accuracy:.4f}  "
        f"p95 {sim.p95_delay*1e3:7.1f}ms"
    )
