"""Aggregate the dry-run artifacts into the 40-cell roofline table.

Reads experiments/dryrun/*.json (produced by repro.launch.dryrun) and
prints the per-cell three-term roofline, dominant bottleneck, useful-FLOPs
ratio, and a memory-efficiency column for decode cells (ideal bytes =
params + cache read once per token vs HLO bytes).
"""
from __future__ import annotations

import glob
import json
import os

from repro.configs import SHAPES, get_config, list_archs
from repro.configs.base import shape_applicable
from repro.models import model as model_lib

ART = os.path.normpath(
    os.path.join(os.path.dirname(__file__), "../experiments/dryrun")
)


def ideal_decode_bytes(arch: str, shape_name: str) -> float:
    """Minimum HBM traffic for one decode step: read every (active) param
    + the KV/state cache once."""
    import jax
    import numpy as np

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n_active = cfg.param_count(active_only=cfg.moe is not None)
    param_bytes = n_active * 2  # bf16
    caches = model_lib.cache_specs(cfg, shape.global_batch, shape.seq_len)
    cache_bytes = sum(
        int(np.prod(l.shape)) * l.dtype.itemsize for l in jax.tree.leaves(caches)
    )
    return param_bytes + cache_bytes


def run() -> list[str]:
    rows = []
    for path in sorted(glob.glob(os.path.join(ART, "*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    by_cell = {r["cell"]: r for r in rows}

    lines = [
        f"{'arch':22s} {'shape':11s} {'mesh':10s} {'comp ms':>8s} {'mem ms':>8s} "
        f"{'coll ms':>8s} {'dom':>6s} {'useful':>7s} {'roofline':>8s} {'mem-eff':>8s}"
    ]
    for arch in list_archs():
        for shape_name in SHAPES:
            cfg = get_config(arch)
            ok, reason = shape_applicable(cfg, SHAPES[shape_name])
            if not ok:
                lines.append(f"{arch:22s} {shape_name:11s} SKIP ({reason.split(':')[0]})")
                continue
            for mesh in ("pod16x16", "pod2x16x16"):
                cell = f"{arch}__{shape_name}__{mesh}"
                r = by_cell.get(cell)
                if r is None:
                    lines.append(f"{arch:22s} {shape_name:11s} {mesh:10s} MISSING")
                    continue
                if "dominant" not in r:
                    lines.append(
                        f"{arch:22s} {shape_name:11s} {mesh:10s} gate-only "
                        f"(compile {r.get('compile_s', '?')}s)"
                    )
                    continue
                mem_eff = ""
                if SHAPES[shape_name].mode == "decode":
                    ideal = ideal_decode_bytes(arch, shape_name)
                    mem_eff = f"{ideal / (r['hlo_gbytes'] * 1e9):8.2f}"
                lines.append(
                    f"{arch:22s} {shape_name:11s} {mesh:10s} "
                    f"{r['compute_ms']:8.2f} {r['memory_ms']:8.2f} "
                    f"{r['collective_ms']:8.2f} {r['dominant'][:6]:>6s} "
                    f"{r['useful_ratio']:7.2f} {r['roofline_fraction']:8.3f} {mem_eff}"
                )
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
