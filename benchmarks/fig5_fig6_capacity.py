"""Figs. 5-6: delay + accuracy vs. average computing resource (0.65x - 1.5x).

The computing mode of every ES is scaled; arrival rates stay fixed.
"""
from __future__ import annotations

from benchmarks.common import ALGOS, decide, fmt_row, run_slot
from repro.core.thresholds import synthetic_validation
from repro.core.topology import build_edge_network, with_capacity_scale
from repro.core.types import BERT_PROFILE, DtoHyperParams, RESNET101_PROFILE

CAP_SCALES = (0.65, 1.0, 1.5)
ARRIVAL = {"resnet101": 2.5, "bert": 0.65}


def run(seed: int = 0, duration: float = 5.0) -> list[str]:
    hyper = DtoHyperParams()
    lines = []
    for profile in (RESNET101_PROFILE, BERT_PROFILE):
        exit_profile = synthetic_validation(seed=seed + 1, profile=profile)
        base = build_edge_network(
            seed=seed, profile=profile, arrival_rate_scale=ARRIVAL[profile.name]
        )
        for cap in CAP_SCALES:
            topo = with_capacity_scale(base, cap)
            lines.append(f"--- {profile.name} capacity x{cap} ---")
            for algo in ALGOS:
                state = decide(algo, topo, profile, exit_profile, hyper, None, static=True)
                sim = run_slot(
                    topo, profile, exit_profile, state, None, duration, seed + 42
                )
                lines.append(fmt_row(algo, sim))
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
