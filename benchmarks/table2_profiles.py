"""Paper Table 2: per-sub-model cost/accuracy profiles.

Prints the paper's profiles (ResNet101 / BERT) and the derived profiles of
the assigned architectures (core.profiles.profile_from_arch), which feed
every other benchmark.
"""
from __future__ import annotations

from repro.configs import get_config, list_archs
from repro.core.profiles import profile_from_arch
from repro.core.types import BERT_PROFILE, RESNET101_PROFILE


def run() -> list[str]:
    lines = []
    for prof in (RESNET101_PROFILE, BERT_PROFILE):
        lines.append(
            f"{prof.name}: alpha={prof.alpha} GFLOPs  beta={prof.beta} MB  "
            f"exits@{prof.exit_stages}  acc={prof.branch_accuracy}"
        )
    for arch in list_archs():
        cfg = get_config(arch)
        prof = profile_from_arch(cfg)
        alpha = tuple(round(a, 2) for a in prof.alpha)
        lines.append(
            f"{arch}: H={prof.num_stages} alpha={alpha} GFLOPs/task "
            f"beta[1:]={prof.beta[1]:.3f} MB exits@{prof.exit_stages}"
        )
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
