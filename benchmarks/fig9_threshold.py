"""Fig. 9: effect of dynamically adjusting confidence thresholds.

DTO-EE vs DTO w/o AT-x (thresholds fixed at x; offloading still optimized)
on the homogeneous deployment, in the dynamic environment.
"""
from __future__ import annotations

import numpy as np

from repro.core import dto_ee, simulator
from repro.core.thresholds import synthetic_validation
from repro.core.topology import build_uniform_network, with_arrival_rates
from repro.core.types import DtoHyperParams, RESNET101_PROFILE

FIXED = (1.0, 0.9, 0.8, 0.7)


def run(seed: int = 0, slots: int = 10, duration: float = 5.0) -> list[str]:
    profile = RESNET101_PROFILE
    hyper = DtoHyperParams()
    exit_profile = synthetic_validation(seed=seed + 1, profile=profile)
    rng = np.random.default_rng(seed + 5)

    variants: dict[str, np.ndarray | None] = {"DTO-EE": None}
    for c in FIXED:
        variants[f"w/o AT-{c}"] = np.full(exit_profile.num_early_branches, c)

    delays = {k: [] for k in variants}
    accs = {k: [] for k in variants}
    topo = build_uniform_network(seed=seed, profile=profile, ed_arrival_rate=2.2)
    states: dict[str, dto_ee.DtoState | None] = {k: None for k in variants}
    for slot in range(slots):
        for name, thr in variants.items():
            adapt = thr is None
            if states[name] is None and thr is not None:
                states[name] = dto_ee.init_state(
                    topo, profile, exit_profile, initial_thresholds=thr
                )
            res = dto_ee.run_configuration_phase(
                topo,
                profile,
                exit_profile,
                hyper,
                state=states[name],
                adapt_thresholds=adapt,
            )
            states[name] = res.state
            sim = simulator.simulate_slot(
                topo,
                profile,
                exit_profile,
                np.asarray(res.state.carry.p),
                res.state.thresholds,
                duration=duration,
                seed=seed + 50 + slot,
            )
            delays[name].append(sim.mean_delay)
            accs[name].append(sim.accuracy)
        topo = with_arrival_rates(topo, rng, 1.2, 3.0)

    lines = []
    d_dto = np.mean(delays["DTO-EE"])
    a_dto = np.mean(accs["DTO-EE"])
    for name in variants:
        d, a = np.mean(delays[name]), np.mean(accs[name])
        lines.append(
            f"{name:12s} delay {d*1e3:7.1f}ms  acc {a:.4f}"
            + (
                f"   (DTO-EE: {(1 - d_dto / d) * 100:+.1f}% delay, "
                f"{(a_dto - a) * 100:+.1f} acc pts)"
                if name != "DTO-EE"
                else ""
            )
        )
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
