"""Benchmark runner: ``PYTHONPATH=src python -m benchmarks.run [--fast]``.

One section per paper table/figure + the roofline table from dry-run
artifacts.  --fast shrinks slot counts for CI-speed runs.
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument(
        "--only", default="", help="comma list: table2,fig34,fig56,fig78,fig9,roofline"
    )
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    def want(name: str) -> bool:
        return only is None or name in only

    sections = []
    if want("table2"):
        from benchmarks import table2_profiles

        sections.append(("Table 2 — sub-model profiles", table2_profiles.run))
    if want("fig34"):
        from benchmarks import fig3_fig4_arrival

        sections.append(
            (
                "Figs. 3-4 — arrival-rate sweep",
                lambda: fig3_fig4_arrival.run(duration=3.0 if args.fast else 5.0),
            )
        )
    if want("fig56"):
        from benchmarks import fig5_fig6_capacity

        sections.append(
            (
                "Figs. 5-6 — capacity sweep",
                lambda: fig5_fig6_capacity.run(duration=3.0 if args.fast else 5.0),
            )
        )
    if want("fig78"):
        from benchmarks import fig7_fig8_dynamic

        sections.append(
            (
                "Figs. 7-8 — dynamic environment",
                lambda: fig7_fig8_dynamic.run(
                    slots=8 if args.fast else 20, group=4 if args.fast else 5
                ),
            )
        )
    if want("fig9"):
        from benchmarks import fig9_threshold

        sections.append(
            (
                "Fig. 9 — dynamic thresholds ablation",
                lambda: fig9_threshold.run(slots=5 if args.fast else 10),
            )
        )
    if want("roofline"):
        from benchmarks import roofline_table

        sections.append(("Roofline table (from dry-run artifacts)", roofline_table.run))

    for title, fn in sections:
        print(f"\n{'=' * 72}\n{title}\n{'=' * 72}", flush=True)
        t0 = time.time()
        for line in fn():
            print(line, flush=True)
        print(f"[{title}: {time.time() - t0:.1f}s]", flush=True)


if __name__ == "__main__":
    main()
