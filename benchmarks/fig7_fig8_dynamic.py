"""Figs. 7-8: the dynamic environment — arrival rates and computing modes
re-randomized every slot; algorithms warm-start and pay their decision time
(the slow deciders route on stale strategies for the first part of each
slot).  Reports per-group means and the delay standard deviation (the
paper's stability metric).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import ALGOS, AlgoState, decide, run_slot
from repro.core.thresholds import synthetic_validation
from repro.core.topology import (
    build_edge_network,
    with_arrival_rates,
    with_resampled_capacities,
)
from repro.core.types import BERT_PROFILE, DtoHyperParams, RESNET101_PROFILE

ARRIVAL = {"resnet101": 3.0, "bert": 0.7}


def run(
    seed: int = 0,
    slots: int = 20,
    group: int = 5,
    duration: float = 5.0,
) -> list[str]:
    hyper = DtoHyperParams()
    lines = []
    for profile in (RESNET101_PROFILE, BERT_PROFILE):
        exit_profile = synthetic_validation(seed=seed + 1, profile=profile)
        rng = np.random.default_rng(seed + 5)
        topo = build_edge_network(
            seed=seed, profile=profile, arrival_rate_scale=ARRIVAL[profile.name]
        )
        lines.append(f"--- {profile.name} dynamic ({slots} slots) ---")
        delays = {a: [] for a in ALGOS}
        accs = {a: [] for a in ALGOS}
        prev: dict[str, AlgoState | None] = {a: None for a in ALGOS}
        for slot in range(slots):
            for algo in ALGOS:
                state = decide(algo, topo, profile, exit_profile, hyper, prev[algo])
                sim = run_slot(
                    topo,
                    profile,
                    exit_profile,
                    state,
                    prev[algo],
                    duration,
                    seed + 100 + slot,
                )
                delays[algo].append(sim.mean_delay)
                accs[algo].append(sim.accuracy)
                prev[algo] = state
            # mutate the environment for the next slot (paper §4.3)
            lo, hi = 0.5 * ARRIVAL[profile.name], 1.5 * ARRIVAL[profile.name]
            topo = with_arrival_rates(topo, rng, lo, hi)
            topo = with_resampled_capacities(topo, rng)
        for algo in ALGOS:
            d = np.asarray(delays[algo])
            a = np.asarray(accs[algo])
            groups = d.reshape(-1, group).mean(axis=1)
            lines.append(
                f"{algo:8s} groups(ms) "
                + " ".join(f"{g*1e3:7.1f}" for g in groups)
                + f"  std {d.std()*1e3:6.1f}ms  acc {a.mean():.4f}"
            )
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
