"""Collaborative inference with DTO-EE vs. static baselines — the paper's
headline experiment run end-to-end against the analytic + simulated stack.

    PYTHONPATH=src python examples/serve_collaborative.py

Deploys the ResNet101 profile (paper Table 2) across a heterogeneous edge
network, optimizes (P, C) with DTO-EE, and measures mean response delay +
accuracy in the discrete-event simulator against CF / BF / NGTO / GA —
each baseline with its own adapted thresholds, as in §4.1.
"""
import numpy as np

from repro.core import baselines, dto_ee, simulator
from repro.core.thresholds import synthetic_validation
from repro.core.topology import build_edge_network
from repro.core.types import DtoHyperParams, RESNET101_PROFILE

profile = RESNET101_PROFILE
hyper = DtoHyperParams()
topo = build_edge_network(seed=0, profile=profile, arrival_rate_scale=3.0)
exit_profile = synthetic_validation(seed=1, profile=profile)

print(f"{len(topo.nodes_at_stage(0))} EDs, stages "
      f"{[len(topo.nodes_at_stage(h)) for h in range(1, profile.num_stages + 1)]}, "
      f"arrival {topo.phi_ext.sum():.1f} tasks/s")

# ---- DTO-EE ---------------------------------------------------------------
res = dto_ee.solve(topo, profile, exit_profile, hyper)
state = res.state
rows = [("DTO-EE", np.asarray(state.carry.p), state.thresholds)]

# ---- baselines (each adapts its own thresholds, paper §4.1) ----------------
for name, p in [
    ("CF", baselines.computing_first(topo)),
    ("BF", baselines.bandwidth_first(topo)),
]:
    thr, _, _ = baselines.adapt_thresholds_for_strategy(
        topo, profile, exit_profile, p, hyper
    )
    rows.append((name, np.asarray(p), thr))

thr0 = np.full(exit_profile.num_early_branches, 0.8)
sr0 = exit_profile.evaluate(thr0).stage_remaining
p_ngto, sweeps = baselines.ngto(topo, profile, sr0)
thr, _, _ = baselines.adapt_thresholds_for_strategy(
    topo, profile, exit_profile, p_ngto, hyper
)
rows.append(("NGTO", np.asarray(p_ngto), thr))

ga = baselines.genetic_paths(topo, profile, sr0, seed=3)
thr, _, _ = baselines.adapt_thresholds_for_strategy(
    topo, profile, exit_profile, ga.p, hyper
)
rows.append(("GA", np.asarray(ga.p), thr))

# ---- measure ----------------------------------------------------------------
print(f"{'algo':8s} {'delay ms':>9s} {'accuracy':>9s} {'p95 ms':>8s}")
results = {}
for name, p, thr in rows:
    sim = simulator.simulate_slot(
        topo, profile, exit_profile, p, thr, duration=5.0, seed=42
    )
    results[name] = sim
    print(f"{name:8s} {sim.mean_delay*1e3:9.1f} {sim.accuracy:9.4f} "
          f"{sim.p95_delay*1e3:8.1f}")

best_baseline = min(v.mean_delay for k, v in results.items() if k != "DTO-EE")
worst_baseline = max(v.mean_delay for k, v in results.items() if k != "DTO-EE")
d = results["DTO-EE"].mean_delay
print(f"\nDTO-EE delay reduction: {(1 - d / best_baseline) * 100:.0f}% vs best "
      f"baseline, {(1 - d / worst_baseline) * 100:.0f}% vs worst (paper: 21-41%)")
