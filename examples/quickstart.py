"""Quickstart: train a tiny staged model, then serve it collaboratively.

    PYTHONPATH=src python examples/quickstart.py

Walks the whole public API in ~2 minutes on CPU:
  1. build a reduced architecture config (same structure as qwen2.5-32b)
  2. train it for 60 steps with the deep-supervision loss (exit heads learn)
  3. deploy it across a small edge topology
  4. run DTO-EE configuration rounds and serve a Poisson request stream,
     watching early exits appear as confidence grows
"""
import jax
import numpy as np

from repro.configs import get_config
from repro.core.profiles import profile_from_arch
from repro.core.thresholds import synthetic_validation
from repro.core.topology import build_edge_network, NetworkSpec
from repro.core.types import DtoHyperParams
from repro.data import DataConfig, token_stream
from repro.models import model as model_lib
from repro.serving import CollaborativeEngine
from repro.training import AdamWConfig, make_train_step
from repro.training import optimizer as opt_lib

# ---- 1. config ------------------------------------------------------------
cfg = get_config("qwen2.5-32b").reduced(vocab_size=256)
print(f"arch: {cfg.name} | {cfg.num_layers}L d={cfg.d_model} "
      f"stages={cfg.num_stages} exits={cfg.exit_stages}")

# ---- 2. train ---------------------------------------------------------------
params = model_lib.init_params(jax.random.key(0), cfg)
opt_state = opt_lib.init_opt_state(params)
step_fn = jax.jit(make_train_step(cfg, AdamWConfig(learning_rate=1e-3, total_steps=60)))
stream = token_stream(cfg, DataConfig(batch_size=8, seq_len=64, seed=0))
for step in range(60):
    params, opt_state, metrics = step_fn(params, opt_state, next(stream))
    if step % 20 == 0 or step == 59:
        print(f"train step {step:3d}  loss {float(metrics['loss']):.3f}  "
              f"exit2 {float(metrics.get('exit_2_loss', 0)):.3f}")

# ---- 3. deploy --------------------------------------------------------------
profile = profile_from_arch(cfg)
topo = build_edge_network(
    seed=0, profile=profile, spec=NetworkSpec(num_eds=6, es_per_stage=(2, 3))
)
exit_profile = synthetic_validation(seed=1, profile=profile)
engine = CollaborativeEngine(
    params, cfg, topo, profile, exit_profile,
    DtoHyperParams(rounds=30), seed=0,
)

# ---- 4. serve ---------------------------------------------------------------
rng = np.random.default_rng(0)
prompts = [rng.integers(0, cfg.vocab_size, size=24).astype(np.int32) for _ in range(16)]
for slot in range(2):
    engine.configuration_phase()
    stats = engine.serve(prompts, duration=2.0)
    s = stats.summary()
    print(f"slot {slot}: completed {s['num_completed']}  "
          f"mean delay {s['mean_delay']*1e3:.1f}ms  exits {s['exit_histogram']}  "
          f"thresholds {np.round(engine.thresholds, 2)}")
print("quickstart OK")
