"""Fault tolerance + elastic scaling demo.

    PYTHONPATH=src python examples/failover_elastic.py

1. Optimize offloading for a healthy network.
2. Kill the most-loaded stage-2 replica -> traffic renormalizes instantly
   (no global coordination), DTO-EE rounds re-balance the survivors.
3. Scale the bottleneck stage out by two replicas (elastic re-mesh,
   warm-started strategy) -> delay recovers below the healthy baseline.
4. Train-side: checkpoint, "crash", restore — bit-exact resume.
"""
import os
import tempfile

import jax
import numpy as np

from repro.configs import get_config
from repro.core import dto_ee, simulator
from repro.core.thresholds import synthetic_validation
from repro.core.topology import build_edge_network
from repro.core.types import DtoHyperParams, RESNET101_PROFILE
from repro.data import DataConfig, token_stream
from repro.models import model as model_lib
from repro.runtime import CheckpointManager, elastic_remesh, handle_failure
from repro.training import AdamWConfig, make_train_step
from repro.training import optimizer as opt_lib

profile = RESNET101_PROFILE
hyper = DtoHyperParams()
topo = build_edge_network(seed=0, profile=profile, arrival_rate_scale=3.0)
ep = synthetic_validation(seed=1, profile=profile)


def measure(topo, p, thr, label):
    sim = simulator.simulate_slot(topo, profile, ep, np.asarray(p), thr, seed=7)
    print(f"{label:28s} delay {sim.mean_delay*1e3:7.1f}ms  "
          f"completed {sim.completed}/{sim.generated}")
    return sim


# ---- 1. healthy -------------------------------------------------------------
res = dto_ee.solve(topo, profile, ep, hyper)
state = res.state
measure(topo, state.carry.p, state.thresholds, "healthy (DTO-EE)")

# ---- 2. failure -------------------------------------------------------------
import jax.numpy as jnp

from repro.core import queueing

stage2 = topo.nodes_at_stage(2)
I_node = jnp.asarray(state.stage_remaining, jnp.float32)[jnp.asarray(topo.node_stage)]
phi, lam = queueing.steady_state_flows(state.carry.p, topo, profile, I_node)
victim = int(stage2[np.argmax(np.asarray(lam)[stage2])])
print(f"\nkilling stage-2 replica node {victim} "
      f"(load {float(lam[victim]):.1f}/{topo.mu[victim]:.0f} GFLOP/s)")
topo2, p2 = handle_failure(topo, np.asarray(state.carry.p), victim)
measure(topo2, p2, state.thresholds, "after failure (renormalized)")

res2 = dto_ee.solve(topo2, profile, ep, hyper, adapt_thresholds=False)
measure(topo2, res2.state.carry.p, state.thresholds, "after DTO-EE re-balance")

# ---- 3. elastic scale-out ----------------------------------------------------
topo3, p3 = elastic_remesh(topo2, np.asarray(res2.state.carry.p), stage=2,
                           add_replicas=2, mu_new=150.0)
res3 = dto_ee.solve(topo3, profile, ep, hyper, adapt_thresholds=False)
measure(topo3, res3.state.carry.p, state.thresholds, "after scale-out (+2 replicas)")

# ---- 4. checkpoint/restart ---------------------------------------------------
print("\ntrain-side crash/restore:")
cfg = get_config("stablelm-1.6b").reduced(vocab_size=256)
params = model_lib.init_params(jax.random.key(0), cfg)
opt = opt_lib.init_opt_state(params)
step_fn = jax.jit(make_train_step(cfg, AdamWConfig(total_steps=20)))
stream = token_stream(cfg, DataConfig(batch_size=4, seq_len=64))
with tempfile.TemporaryDirectory() as d:
    ckpt = CheckpointManager(d)
    for step in range(6):
        params, opt, m = step_fn(params, opt, next(stream))
        if step == 2:
            ckpt.save(3, (params, opt))
            saved_loss_stream = []
    # "crash": rebuild from disk and replay steps 3..5
    (params_r, opt_r), manifest = ckpt.restore(
        jax.eval_shape(lambda: (params, opt))
    )
    stream_r = token_stream(cfg, DataConfig(batch_size=4, seq_len=64), start_step=3)
    for step in range(3, 6):
        params_r, opt_r, m = step_fn(params_r, opt_r, next(stream_r))
    diff = max(
        float(abs(a - b).max())
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params_r))
    )
    print(f"restored-replay max param divergence: {diff:.2e} (bit-exact resume)")
