"""End-to-end behaviour: paper-claim checks + a real (small-mesh) dry-run
in a subprocess (device-count override must not leak into this process)."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_paper_claim_dto_ee_beats_baselines_static():
    """DTO-EE: lower delay than CF/BF (Figs 3-4 regime, simulated)."""
    from repro.core import baselines, dto_ee, simulator
    from repro.core.thresholds import synthetic_validation
    from repro.core.topology import build_edge_network
    from repro.core.types import DtoHyperParams, RESNET101_PROFILE

    profile = RESNET101_PROFILE
    hyper = DtoHyperParams()
    topo = build_edge_network(seed=0, profile=profile, arrival_rate_scale=3.0)
    ep = synthetic_validation(seed=1, profile=profile)
    res = dto_ee.solve(topo, profile, ep, hyper)
    p_dto, thr = np.asarray(res.state.carry.p), res.state.thresholds
    dto = simulator.simulate_slot(topo, profile, ep, p_dto, thr, seed=42)

    for p_b in (baselines.computing_first(topo), baselines.bandwidth_first(topo)):
        thr_b, _, _ = baselines.adapt_thresholds_for_strategy(
            topo, profile, ep, p_b, hyper
        )
        sim_b = simulator.simulate_slot(
            topo, profile, ep, np.asarray(p_b), thr_b, seed=42
        )
        assert dto.mean_delay < sim_b.mean_delay * 0.9  # >=10% better


def test_paper_claim_threshold_ablation_direction():
    """DTO-EE vs fixed-1.0: >=15% lower delay at <=1.5pt accuracy cost."""
    from repro.core import dto_ee, simulator
    from repro.core.thresholds import synthetic_validation
    from repro.core.topology import build_uniform_network
    from repro.core.types import DtoHyperParams, RESNET101_PROFILE

    profile = RESNET101_PROFILE
    hyper = DtoHyperParams()
    ep = synthetic_validation(seed=1, profile=profile)
    topo = build_uniform_network(seed=0, profile=profile, ed_arrival_rate=2.2)

    res = dto_ee.solve(topo, profile, ep, hyper)
    dto = simulator.simulate_slot(
        topo, profile, ep, np.asarray(res.state.carry.p), res.state.thresholds, seed=5
    )
    res10 = dto_ee.solve(topo, profile, ep, hyper, adapt_thresholds=False)
    base = simulator.simulate_slot(
        topo,
        profile,
        ep,
        np.asarray(res10.state.carry.p),
        np.ones(ep.num_early_branches),
        seed=5,
    )
    assert dto.mean_delay < base.mean_delay * 0.85
    # the utility tradeoff may spend a few accuracy points for the delay cut;
    # it must stay within the paper's 1-5pt band and win on utility U (Eq. 9)
    assert dto.accuracy > base.accuracy - 0.05
    from repro.core.thresholds import synthetic_validation as _sv
    from repro.core.utility import utility

    a = hyper.utility_a
    u_dto = utility(dto.mean_delay, ep.normalized_accuracy(dto.accuracy), a)
    u_base = utility(base.mean_delay, ep.normalized_accuracy(base.accuracy), a)
    assert u_dto < u_base


@pytest.mark.slow
def test_dryrun_cell_compiles_in_subprocess():
    """A real (reduced-arch) lower+compile on a forced 16-device host mesh."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
import jax, json
import numpy as np
from repro.configs import get_config, SHAPES
from repro.launch import dryrun
# dryrun imported the symbol directly; patch it there
dryrun.make_production_mesh = lambda multi_pod=False: jax.make_mesh(
    (2, 2, 4) if multi_pod else (4, 4),
    ("pod", "data", "model") if multi_pod else ("data", "model"),
)
import repro.configs.registry as reg
cfg = reg.get_config("stablelm-1.6b").reduced(vocab_size=512)
reg._cache["stablelm-1.6b"] = cfg
# gates only: full fits are too heavy for a contended 1-core CI box
row = dryrun.run_cell("stablelm-1.6b", "train_4k", multi_pod=False, fit=False, save=False)
assert row.get("gate") == "ok", row
row2 = dryrun.run_cell("stablelm-1.6b", "decode_32k", multi_pod=True, fit=False, save=False)
assert row2.get("gate") == "ok", row2
print("SUBPROCESS_OK", row["memory"].get("peak_gb_per_device_tpu"))
"""
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        env=env,
        timeout=1200,
    )
    assert "SUBPROCESS_OK" in out.stdout, out.stdout + out.stderr


def test_data_pipeline_deterministic_resume():
    from repro.configs import get_config
    from repro.data import DataConfig, token_stream

    cfg = get_config("stablelm-1.6b").reduced()
    dcfg = DataConfig(batch_size=2, seq_len=16, seed=3)
    a = token_stream(cfg, dcfg, start_step=0)
    batches = [next(a) for _ in range(5)]
    b = token_stream(cfg, dcfg, start_step=3)
    resumed = next(b)
    np.testing.assert_array_equal(
        np.asarray(batches[3]["tokens"]), np.asarray(resumed["tokens"])
    )
