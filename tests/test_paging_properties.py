"""Hypothesis property tests for ``serving.paging.BlockAllocator``.

A stateful machine drives random alloc / fork / append / free schedules
against a shadow model of the device pool (a Python list per block) and
checks, after every step:

  * refcounts equal the number of live block-table references per block;
  * no double-free (freeing a retired handle raises; internal rc never < 0);
  * freed blocks are reused before never-used ones ("pool growth");
  * copy-on-write never mutates a block another sequence reads — every live
    sequence reads back exactly its own token history through its table;
  * prefix sharing only ever shares blocks with identical content.

Run locally with ``pip install -r requirements-dev.txt``; CI runs a longer
seeded pass via ``HYPOTHESIS_PROFILE=ci-fuzz``.
"""
import os

import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings, strategies as st  # noqa: E402
from hypothesis.stateful import (  # noqa: E402
    RuleBasedStateMachine,
    invariant,
    precondition,
    rule,
)

from repro.serving.paging import BlockAllocator, blocks_for  # noqa: E402

settings.register_profile(
    "ci-fuzz",
    max_examples=600,
    stateful_step_count=80,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow],
)
settings.register_profile(
    "local",
    max_examples=30,
    stateful_step_count=40,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow],
)
settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "local"))

NUM_BLOCKS, BLOCK_SIZE = 24, 4


class AllocatorMachine(RuleBasedStateMachine):
    def __init__(self):
        super().__init__()
        self.alloc = BlockAllocator(NUM_BLOCKS, BLOCK_SIZE, prefix_sharing=True)
        # shadow of the device pool: one content cell per (block, offset)
        self.blocks = [[None] * BLOCK_SIZE for _ in range(NUM_BLOCKS)]
        self.seqs: dict[int, list] = {}  # handle -> expected token history
        self.next_token = 10_000  # appended tokens are globally unique

    def _assert_reuse_before_growth(self, fresh_before: int) -> None:
        if self.alloc._fresh > fresh_before:
            assert not self.alloc._free, (
                "took never-used blocks while freed blocks were available"
            )

    @rule(data=st.data())
    def alloc_prompt(self, data):
        # tiny token alphabet: prefix collisions (sharing) happen constantly
        toks = data.draw(
            st.lists(st.integers(0, 3), min_size=1, max_size=3 * BLOCK_SIZE + 2)
        )
        fresh_before = self.alloc._fresh
        res = self.alloc.alloc(toks)
        if res is None:
            return
        self._assert_reuse_before_growth(fresh_before)
        assert len(res.table) == blocks_for(len(toks), BLOCK_SIZE)
        for j, (blk, shared) in enumerate(zip(res.table, res.shared)):
            chunk = list(toks[j * BLOCK_SIZE : (j + 1) * BLOCK_SIZE])
            if shared:
                # sharing must be content-exact — the block already holds
                # precisely this (full) chunk
                assert len(chunk) == BLOCK_SIZE
                assert self.blocks[blk][: len(chunk)] == chunk
            else:
                for o, t in enumerate(chunk):
                    self.blocks[blk][o] = t
        self.seqs[res.handle] = list(toks)

    @precondition(lambda self: self.seqs)
    @rule(data=st.data())
    def fork_seq(self, data):
        h = data.draw(st.sampled_from(sorted(self.seqs)))
        nh = self.alloc.fork(h)
        assert nh not in self.seqs
        self.seqs[nh] = list(self.seqs[h])

    @precondition(lambda self: self.seqs)
    @rule(data=st.data())
    def append_token(self, data):
        h = data.draw(st.sampled_from(sorted(self.seqs)))
        fresh_before = self.alloc._fresh
        res = self.alloc.append(h)
        if res is None:
            assert self.alloc.free_blocks == 0
            return
        self._assert_reuse_before_growth(fresh_before)
        if res.cow is not None:
            src, dst = res.cow
            assert res.block == dst
            self.blocks[dst] = list(self.blocks[src])  # the device block copy
        tok = self.next_token
        self.next_token += 1
        self.blocks[res.block][res.offset] = tok
        self.seqs[h].append(tok)

    @precondition(lambda self: self.seqs)
    @rule(data=st.data())
    def free_seq(self, data):
        h = data.draw(st.sampled_from(sorted(self.seqs)))
        self.alloc.free(h)
        del self.seqs[h]
        with pytest.raises(ValueError):
            self.alloc.free(h)  # double free must raise, not corrupt

    # -- invariants, checked after every step -------------------------------

    @invariant()
    def refcounts_match_live_references(self):
        counts = [0] * NUM_BLOCKS
        for h in self.seqs:
            for b in self.alloc.table(h):
                counts[b] += 1
        assert counts == self.alloc.refcounts()

    @invariant()
    def pool_accounting_consistent(self):
        assert 0 <= self.alloc.free_blocks <= NUM_BLOCKS
        live = sum(1 for rc in self.alloc.refcounts() if rc > 0)
        assert self.alloc.used_blocks == live

    @invariant()
    def every_sequence_reads_back_its_own_history(self):
        """The central COW/aliasing property: shared blocks are never
        mutated, so each live table resolves to exactly its own tokens."""
        for h, toks in self.seqs.items():
            tab = self.alloc.table(h)
            got = [
                self.blocks[tab[j // BLOCK_SIZE]][j % BLOCK_SIZE]
                for j in range(len(toks))
            ]
            assert got == toks


TestAllocatorStateMachine = AllocatorMachine.TestCase


# ---------------------------------------------------------------------------
# direct properties
# ---------------------------------------------------------------------------


@given(st.integers(1, 40), st.integers(1, 7))
def test_freed_blocks_reused_before_growth(n_tokens, block_size):
    a = BlockAllocator(64, block_size, prefix_sharing=False)
    r1 = a.alloc(list(range(n_tokens)))
    high_water = a._fresh
    a.free(r1.handle)
    r2 = a.alloc(list(range(1000, 1000 + n_tokens)))
    assert sorted(r2.table) == sorted(r1.table)
    assert a._fresh == high_water  # no growth: the freed blocks sufficed


@given(st.lists(st.integers(0, 5), min_size=1, max_size=20))
def test_identical_prompts_share_exactly_the_full_blocks(toks):
    a = BlockAllocator(32, BLOCK_SIZE)
    r1 = a.alloc(toks)
    r2 = a.alloc(toks)
    n_full = len(toks) // BLOCK_SIZE
    assert r2.shared == [True] * n_full + [False] * (len(r2.table) - n_full)
    assert r2.table[:n_full] == r1.table[:n_full]
    for b in r1.table[:n_full]:
        assert a.refcount(b) == 2


def test_double_free_raises_and_leaves_pool_intact():
    a = BlockAllocator(4, 2)
    r = a.alloc([1, 2, 3])
    a.free(r.handle)
    free_after = a.free_blocks
    with pytest.raises(ValueError):
        a.free(r.handle)
    assert a.free_blocks == free_after == 4


def test_copy_on_write_moves_writer_not_reader():
    a = BlockAllocator(8, 4)
    r = a.alloc([1, 2, 3])  # one partial block
    f = a.fork(r.handle)
    res = a.append(f)  # position 3 falls in the shared partial block
    assert res is not None and res.cow is not None
    src, dst = res.cow
    assert a.table(r.handle) == [src]  # the reader keeps the original
    assert a.table(f) == [dst]  # the writer moved to a private copy
    assert a.refcount(src) == 1 and a.refcount(dst) == 1


def test_append_crossing_block_boundary_takes_fresh_block():
    a = BlockAllocator(8, 2)
    r = a.alloc([1, 2])  # exactly one full block
    res = a.append(r.handle)
    assert res is not None and res.new_block and res.cow is None
    assert res.offset == 0 and len(a.table(r.handle)) == 2


def test_alloc_returning_none_leaves_no_partial_state():
    a = BlockAllocator(2, 2, prefix_sharing=False)
    r1 = a.alloc([1, 2, 3])  # 2 blocks: pool now full
    assert r1 is not None and a.free_blocks == 0
    assert a.alloc([9, 9, 9]) is None
    assert a.free_blocks == 0 and a.live_handles() == [r1.handle]
    a.free(r1.handle)
    assert a.free_blocks == 2
