"""Queueing model: flow conservation, Eq. 22 gradient oracle, simulator
agreement with the analytic M/D/1-PS formulas."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need the dev extra
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.core import dto_ee, gradients, penalty, queueing, simulator
from repro.core.thresholds import synthetic_validation
from repro.core.topology import build_edge_network, build_uniform_network
from repro.core.types import DtoHyperParams, RESNET101_PROFILE

PROFILE = RESNET101_PROFILE


def _setup(seed=0, scale=2.0):
    topo = build_edge_network(seed=seed, profile=PROFILE, arrival_rate_scale=scale)
    ep = synthetic_validation(seed=seed + 1, profile=PROFILE)
    ev = ep.evaluate(np.array([0.7, 0.7]))
    I_node = jnp.asarray(ev.stage_remaining, jnp.float32)[jnp.asarray(topo.node_stage)]
    return topo, ep, I_node


def test_flow_conservation():
    """Stage-h inflow == upstream outflow x remaining ratios (Eq. 3)."""
    topo, ep, I_node = _setup()
    p = dto_ee.uniform_strategy(topo)
    phi, lam = queueing.steady_state_flows(p, topo, PROFILE, I_node)
    phi = np.asarray(phi)
    I_np = np.asarray(I_node)
    total_in = topo.phi_ext.sum()
    for h in range(1, PROFILE.num_stages + 1):
        stage_nodes = topo.nodes_at_stage(h)
        upstream = topo.nodes_at_stage(h - 1)
        expected = np.sum(phi[upstream] * I_np[upstream])
        np.testing.assert_allclose(phi[stage_nodes].sum(), expected, rtol=1e-5)
    # nothing is created: stage-1 inflow <= total external arrivals
    assert phi[topo.nodes_at_stage(1)].sum() <= total_in * 1.0001


@given(seed=st.integers(0, 30))
@settings(max_examples=8, deadline=None)
def test_eq22_analytic_gradient_matches_autodiff(seed):
    """The paper's dR/dp = (phi I / Phi) Delta (Eq. 22) == jax.grad of R.

    Eq. 22 holds in the stable interior (lam < mu); outside it the
    implementation intentionally clamps Delta to a large constant (the
    distributed algorithm's escape signal), so unstable draws are lightened
    by reducing the arrival scale until stable.
    """
    from hypothesis import assume

    topo, ep, I_node = _setup(seed=seed, scale=1.2)
    hyper = DtoHyperParams()
    rng = np.random.default_rng(seed)
    # random feasible interior strategy
    raw = rng.uniform(0.2, 1.0, topo.num_edges)
    sums = np.zeros(topo.num_nodes)
    np.add.at(sums, topo.edge_src, raw)
    p = jnp.asarray(raw / sums[topo.edge_src], jnp.float32)

    _, lam = queueing.steady_state_flows(p, topo, PROFILE, I_node)
    # margin keeps autodiff away from the penalty kink at lam == mu - eps
    mu = np.where(np.isinf(topo.mu), 1e30, topo.mu)
    assume(bool(np.all(np.asarray(lam) < 0.95 * mu)))

    analytic = gradients.analytic_gradient(p, topo, PROFILE, I_node, hyper)
    auto = jax.grad(lambda q: penalty.objective_r(q, topo, PROFILE, I_node, hyper))(p)
    np.testing.assert_allclose(
        np.asarray(analytic), np.asarray(auto), rtol=2e-2, atol=1e-3
    )


def test_mdps_queue_sim_matches_formula():
    """A single M/D/1-PS queue's mean sojourn time == alpha/(mu - lambda)."""
    import dataclasses

    # 1 ED -> 1 ES topology
    from repro.core.types import ModelProfile, Topology

    prof = ModelProfile(
        name="one",
        alpha=(2.0,),
        beta=(0.001, ),
        has_exit=(False,),
        branch_accuracy=(0.6,),
    )
    lam_rate = 20.0  # tasks/s
    mu = 60.0  # GFLOP/s -> rho = 20*2/60 = 0.667
    topo = Topology(
        node_stage=np.array([0, 1], np.int32),
        mu=np.array([np.inf, mu]),
        phi_ext=np.array([lam_rate, 0.0]),
        edge_src=np.array([0], np.int32),
        edge_dst=np.array([1], np.int32),
        edge_rate=np.array([1e9]),
        edge_offsets=np.array([0, 1, 1], np.int32),
    )
    ep = synthetic_validation(seed=0, profile=prof)
    sim = simulator.simulate_slot(
        topo,
        prof,
        ep,
        p=np.array([1.0]),
        thresholds=np.zeros(0),
        duration=60.0,
        seed=3,
    )
    expected = prof.alpha[0] / (mu - lam_rate * prof.alpha[0])  # Eq. 6
    assert sim.completed > 800
    np.testing.assert_allclose(sim.mean_delay, expected, rtol=0.1)


def test_average_delay_matches_simulator_end_to_end():
    """Analytic T (Eq. 8) within ~12% of the event simulator."""
    topo, ep, I_node = _setup(scale=2.5)
    hyper = DtoHyperParams()
    res = dto_ee.solve(topo, PROFILE, ep, hyper, adapt_thresholds=False)
    p = res.state.carry.p
    t_analytic, _, stable = dto_ee.evaluate_strategy(p, topo, PROFILE, I_node, hyper)
    assert stable
    thr = np.array([0.7, 0.7])
    sim = simulator.simulate_slot(
        topo, PROFILE, ep, np.asarray(p), thr, duration=10.0, seed=9
    )
    assert abs(sim.mean_delay - t_analytic) / t_analytic < 0.15


def test_unstable_configuration_detected():
    topo = build_uniform_network(
        seed=0, profile=PROFILE, num_eds=30, es_per_stage=2,
        capacity_gflops=10.0, ed_arrival_rate=3.0,
    )
    p = dto_ee.uniform_strategy(topo)
    I_node = jnp.ones(topo.num_nodes)
    _, lam = queueing.steady_state_flows(p, topo, PROFILE, I_node)
    assert not bool(queueing.is_stable(topo, lam))
    t = queueing.compute_delay_per_node(topo, PROFILE, lam)
    assert bool(jnp.all(jnp.isfinite(t)))  # penalty handles it, no NaN/inf
