"""Training substrate: optimizer math, microbatch equivalence, loss
decrease, int8 compression with error feedback."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data import DataConfig, token_stream
from repro.models import model as model_lib
from repro.runtime import compression
from repro.training import AdamWConfig, make_train_step
from repro.training import optimizer as opt_lib
from repro.training.train_step import accumulate_grads


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("stablelm-1.6b").reduced(vocab_size=128)
    params = model_lib.init_params(jax.random.key(0), cfg)
    return cfg, params


def test_adamw_matches_reference_scalar():
    """One AdamW step on a 2-vector vs hand-computed update."""
    p = {"w": jnp.asarray([[1.0, -2.0]])}
    g = {"w": jnp.asarray([[0.5, 0.1]])}
    cfg = AdamWConfig(
        learning_rate=0.1, beta1=0.9, beta2=0.999, eps=1e-8,
        weight_decay=0.0, grad_clip_norm=0.0, warmup_steps=0, total_steps=10**9,
    )
    state = opt_lib.init_opt_state(p)
    p2, state2, _ = opt_lib.adamw_update(p, g, state, cfg)
    m = 0.1 * np.array([0.5, 0.1])
    v = 0.001 * np.array([0.5, 0.1]) ** 2
    mh, vh = m / 0.1, v / 0.001
    expect = np.array([1.0, -2.0]) - 0.1 * mh / (np.sqrt(vh) + 1e-8)
    np.testing.assert_allclose(np.asarray(p2["w"])[0], expect, rtol=1e-5)
    assert int(state2["step"]) == 1


def test_grad_clip_norm():
    g = {"a": jnp.ones((10,)) * 3.0}
    clipped, norm = opt_lib.clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(norm), 3.0 * np.sqrt(10), rtol=1e-6)
    np.testing.assert_allclose(
        float(opt_lib.global_norm(clipped)), 1.0, rtol=1e-5
    )


def test_microbatch_grads_equal_full_batch(tiny):
    cfg, params = tiny
    rng = np.random.default_rng(0)
    B, S = 4, 16
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
    }

    # per-microbatch mean losses averaged == full-batch mean only when
    # token counts match per microbatch; labels here are all unmasked.
    def loss(p, b):
        return model_lib.loss_fn(p, b, cfg)

    l1, g1, _ = accumulate_grads(loss, params, batch, 1)
    l2, g2, _ = accumulate_grads(loss, params, batch, 2)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-4)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), atol=3e-3
        )


def test_loss_decreases(tiny):
    cfg, params = tiny
    opt_cfg = AdamWConfig(learning_rate=2e-3, total_steps=30, warmup_steps=5)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg))
    opt_state = opt_lib.init_opt_state(params)
    stream = token_stream(cfg, DataConfig(batch_size=4, seq_len=32, seed=0))
    losses = []
    for _ in range(30):
        params, opt_state, metrics = step_fn(params, opt_state, next(stream))
        losses.append(float(metrics["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1
    assert np.isfinite(losses).all()


def test_int8_roundtrip_error_bound(rng):
    x = jnp.asarray(rng.standard_normal((64, 64)) * 0.01, jnp.float32)
    q, s = compression.quantize_int8(x)
    back = compression.dequantize_int8(q, s)
    assert float(jnp.max(jnp.abs(back - x))) <= float(s) * 0.5 + 1e-9


def test_error_feedback_is_unbiased_over_steps(rng):
    """With error feedback, the accumulated dequantized sum tracks the true
    gradient sum (bias does not grow with steps)."""
    true_sum = np.zeros((8, 8), np.float32)
    sent_sum = np.zeros((8, 8), np.float32)
    err = {"g": jnp.zeros((8, 8), jnp.float32)}
    for t in range(50):
        g = {"g": jnp.asarray(rng.standard_normal((8, 8)) * 0.1, jnp.float32)}
        q, s, err = compression.compress_tree(g, err)
        sent = compression.decompress_tree(q, s)
        true_sum += np.asarray(g["g"])
        sent_sum += np.asarray(sent["g"])
    # residual bounded by one quantization step, not O(T)
    resid = np.abs(true_sum - sent_sum).max()
    assert resid < 0.02


def test_train_step_metrics_keys(tiny):
    cfg, params = tiny
    step_fn = jax.jit(make_train_step(cfg, AdamWConfig(total_steps=5)))
    opt_state = opt_lib.init_opt_state(params)
    stream = token_stream(cfg, DataConfig(batch_size=2, seq_len=16))
    _, _, metrics = step_fn(params, opt_state, next(stream))
    for key in ("loss", "grad_norm", "lr", "final_loss"):
        assert key in metrics
