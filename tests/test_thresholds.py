"""Accuracy-ratio table (reuse-based one-shot evaluation): invariants."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need the dev extra
from hypothesis import given, settings, strategies as st

from repro.core.thresholds import synthetic_validation
from repro.core.types import BERT_PROFILE, RESNET101_PROFILE


@pytest.fixture(scope="module")
def ep():
    return synthetic_validation(seed=0, profile=RESNET101_PROFILE)


def test_extremes(ep):
    """c=1 -> nobody exits early (A_max); c=0 -> everyone exits at branch 0."""
    hi = ep.evaluate(np.ones(ep.num_early_branches))
    assert hi.exit_fraction[-1] == pytest.approx(1.0)
    assert hi.accuracy == pytest.approx(ep.acc_max)
    lo = ep.evaluate(np.zeros(ep.num_early_branches))
    assert lo.exit_fraction[0] == pytest.approx(1.0)
    assert lo.accuracy == pytest.approx(ep.acc_min)


@given(
    c=st.tuples(
        st.floats(0.0, 1.0, allow_nan=False), st.floats(0.0, 1.0, allow_nan=False)
    )
)
@settings(max_examples=40, deadline=None)
def test_exit_fractions_partition(ep, c):
    ev = ep.evaluate(np.asarray(c))
    assert ev.exit_fraction.sum() == pytest.approx(1.0)
    assert np.all(ev.exit_fraction >= 0)
    assert np.all(ev.stage_remaining >= 0) and np.all(ev.stage_remaining <= 1)


def test_remaining_ratio_monotone_in_threshold(ep):
    """Raising c_b keeps more tasks in the pipeline at stage b."""
    rs = [
        ep.evaluate([c, 0.8]).stage_remaining[ep.branch_stage[0]]
        for c in (0.2, 0.5, 0.8, 1.0)
    ]
    assert all(a <= b + 1e-12 for a, b in zip(rs, rs[1:]))


def test_accuracy_monotone_under_synthetic_defaults(ep):
    """With the tuned defaults the paper's tradeoff holds: higher thresholds
    -> higher accuracy (so lowering c trades accuracy for delay)."""
    accs = [ep.evaluate([c, c]).accuracy for c in (0.0, 0.4, 0.7, 1.0)]
    assert all(a <= b + 0.01 for a, b in zip(accs, accs[1:]))


def test_accuracy_ratio_table_consistency(ep):
    """Table screening == direct evaluation (the reuse trick is exact)."""
    grid = np.array([0.5, 0.8])
    table = ep.accuracy_ratio_table(grid)
    for combo, ev in table.items():
        direct = ep.evaluate(np.asarray(combo))
        assert ev.accuracy == pytest.approx(direct.accuracy)
        np.testing.assert_allclose(ev.stage_remaining, direct.stage_remaining)


def test_bert_profile_has_three_branches():
    ep_b = synthetic_validation(seed=0, profile=BERT_PROFILE)
    assert ep_b.num_early_branches == 3
    assert ep_b.branch_stage == (2, 3, 4, 5)
