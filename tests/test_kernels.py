"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.exit_confidence import exit_confidence
from repro.kernels.flash_attention import flash_attention
from repro.kernels.paged_decode_attention import paged_decode_attention

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def _rand(rng, shape, dtype):
    return jnp.asarray(rng.standard_normal(shape), dtype)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,Sq,Sk,Hq,KVH,hd,block",
    [
        (1, 128, 128, 4, 4, 64, 64),  # MHA
        (2, 256, 256, 8, 2, 64, 64),  # GQA 4:1
        (1, 192, 192, 4, 1, 32, 64),  # MQA, ragged seq vs block
        (2, 128, 384, 4, 4, 128, 128),  # cross: kv longer than q
    ],
)
def test_flash_attention_matches_ref(rng, dtype, B, Sq, Sk, Hq, KVH, hd, block):
    q = _rand(rng, (B, Sq, Hq, hd), dtype)
    k = _rand(rng, (B, Sk, KVH, hd), dtype)
    v = _rand(rng, (B, Sk, KVH, hd), dtype)
    out = flash_attention(
        q, k, v, causal=True, block_q=block, block_k=block, interpret=True
    )
    exp = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(exp, np.float32), atol=TOL[dtype]
    )


@pytest.mark.parametrize("window", [32, 100, 4096])
def test_flash_attention_sliding_window(rng, window):
    B, S, H, hd = 1, 256, 4, 64
    q = _rand(rng, (B, S, H, hd), jnp.float32)
    k = _rand(rng, (B, S, H, hd), jnp.float32)
    v = _rand(rng, (B, S, H, hd), jnp.float32)
    out = flash_attention(
        q, k, v, causal=True, window=window, block_q=64, block_k=64, interpret=True
    )
    exp = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=2e-5)


def test_flash_attention_non_causal(rng):
    B, S, H, hd = 1, 128, 2, 64
    q = _rand(rng, (B, S, H, hd), jnp.float32)
    k = _rand(rng, (B, S, H, hd), jnp.float32)
    v = _rand(rng, (B, S, H, hd), jnp.float32)
    out = flash_attention(q, k, v, causal=False, block_q=64, block_k=64, interpret=True)
    exp = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=2e-5)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,S,Hq,KVH,hd,block",
    [
        (2, 300, 8, 2, 64, 64),
        (1, 512, 4, 4, 128, 128),
        (3, 1000, 16, 4, 64, 256),  # ragged lengths below
    ],
)
def test_decode_attention_matches_ref(rng, dtype, B, S, Hq, KVH, hd, block):
    q = _rand(rng, (B, Hq, hd), dtype)
    k = _rand(rng, (B, S, KVH, hd), dtype)
    v = _rand(rng, (B, S, KVH, hd), dtype)
    lengths = jnp.asarray(rng.integers(1, S + 1, size=B), jnp.int32)
    out = decode_attention(q, k, v, lengths, block_k=block, interpret=True)
    exp = ref.decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(exp, np.float32), atol=TOL[dtype]
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,Hq,KVH,hd,NB,bs,nlog",
    [
        (3, 4, 2, 32, 9, 16, 4),  # GQA 2:1
        (2, 2, 2, 16, 5, 1, 7),  # degenerate one-token blocks
        (1, 8, 4, 64, 12, 8, 3),  # single row
    ],
)
def test_paged_decode_attention_matches_oracle(rng, dtype, B, Hq, KVH, hd, NB, bs, nlog):
    """Scalar-prefetch block-table kernel == gather + dense decode oracle."""
    q = _rand(rng, (B, Hq, hd), dtype)
    k_pool = _rand(rng, (NB, bs, KVH, hd), dtype)
    v_pool = _rand(rng, (NB, bs, KVH, hd), dtype)
    table = jnp.asarray(rng.integers(0, NB, (B, nlog)), jnp.int32)
    lengths = jnp.asarray(rng.integers(1, nlog * bs + 1, (B,)), jnp.int32)
    want = ref.paged_decode_attention_ref(q, k_pool, v_pool, table, lengths)
    got = paged_decode_attention(q, k_pool, v_pool, table, lengths, interpret=True)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=TOL[dtype]
    )


def test_paged_dispatch_backends_agree_on_seq_len(rng):
    """ops.paged_decode_attention must honor seq_len identically on the xla
    (gather + slice) and Pallas (length-clamp) paths, including rows whose
    raw length overhangs seq_len."""
    from repro.kernels import ops

    B, Hq, KVH, hd, NB, bs, nlog = 3, 4, 2, 32, 10, 8, 4
    q = _rand(rng, (B, Hq, hd), jnp.float32)
    k_pool = _rand(rng, (NB, bs, KVH, hd), jnp.float32)
    v_pool = _rand(rng, (NB, bs, KVH, hd), jnp.float32)
    table = jnp.asarray(rng.integers(0, NB, (B, nlog)), jnp.int32)
    seq_len = 20  # < nlog * bs
    lengths = jnp.asarray([5, seq_len, nlog * bs], jnp.int32)  # last overhangs
    try:
        ops.set_backend("xla")
        want = ops.paged_decode_attention(
            q, k_pool, v_pool, table, lengths, seq_len=seq_len
        )
        ops.set_backend("pallas_interpret")
        got = ops.paged_decode_attention(
            q, k_pool, v_pool, table, lengths, seq_len=seq_len
        )
    finally:
        ops.set_backend("auto")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_paged_oracle_seq_len_slice_matches_contiguous(rng):
    """A block table laid out contiguously + seq_len slice reproduces the
    dense decode reference on the same rows — the bitwise bridge the paged
    serving path rests on."""
    B, S, KVH, Hq, hd, bs = 2, 20, 2, 4, 32, 8
    nlog = -(-S // bs)
    k = _rand(rng, (B, S, KVH, hd), jnp.float32)
    v = _rand(rng, (B, S, KVH, hd), jnp.float32)
    q = _rand(rng, (B, Hq, hd), jnp.float32)
    lengths = jnp.asarray([S, 13], jnp.int32)
    pad = nlog * bs - S
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    # row b's blocks live at pool rows [b*nlog, (b+1)*nlog)
    k_pool = kp.reshape(B * nlog, bs, KVH, hd)
    v_pool = vp.reshape(B * nlog, bs, KVH, hd)
    table = jnp.arange(B * nlog, dtype=jnp.int32).reshape(B, nlog)
    want = ref.decode_attention_ref(q, k, v, lengths)
    got = ref.paged_decode_attention_ref(
        q, k_pool, v_pool, table, lengths, seq_len=S
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_decode_attention_length_zero_rows_are_finite(rng):
    B, S, Hq, KVH, hd = 2, 128, 4, 4, 32
    q = _rand(rng, (B, Hq, hd), jnp.float32)
    k = _rand(rng, (B, S, KVH, hd), jnp.float32)
    v = _rand(rng, (B, S, KVH, hd), jnp.float32)
    lengths = jnp.asarray([0, 64], jnp.int32)
    out = decode_attention(q, k, v, lengths, block_k=64, interpret=True)
    assert bool(jnp.all(jnp.isfinite(out)))
    assert bool(jnp.all(out[0] == 0.0))  # empty cache -> zero output


# ---------------------------------------------------------------------------
# exit confidence
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,d,V,bb,bv",
    [
        (4, 64, 1000, 4, 256),  # ragged vocab
        (8, 128, 2048, 4, 512),
        (3, 32, 513, 8, 128),  # B < block, V % block != 0
        (1, 16, 257, 8, 128),  # single row, ragged vocab tail of 1
        (5, 16, 130, 4, 64),  # batch pad + vocab pad simultaneously
        (7, 32, 64, 2, 64),  # vocab fits one block exactly, batch ragged
        (6, 16, 127, 8, 128),  # vocab < one block (block_v clamps to V)
    ],
)
def test_exit_confidence_matches_ref(rng, dtype, B, d, V, bb, bv):
    h = _rand(rng, (B, d), dtype)
    w = _rand(rng, (d, V), dtype)
    conf, idx = exit_confidence(h, w, block_b=bb, block_v=bv, interpret=True)
    cref, iref = ref.exit_confidence_ref(h, w)
    np.testing.assert_allclose(np.asarray(conf), np.asarray(cref), atol=1e-3)
    assert bool(jnp.all(idx == iref))


def test_exit_confidence_padding_rows_do_not_leak(rng):
    """Padded batch rows must not perturb real rows' (conf, argmax)."""
    h = _rand(rng, (3, 32), jnp.float32)
    w = _rand(rng, (32, 200), jnp.float32)
    conf3, idx3 = exit_confidence(h, w, block_b=8, block_v=64, interpret=True)
    h_pad = jnp.concatenate([h, jnp.zeros((5, 32), jnp.float32)])
    conf8, idx8 = exit_confidence(h_pad, w, block_b=8, block_v=64, interpret=True)
    np.testing.assert_allclose(np.asarray(conf8[:3]), np.asarray(conf3), atol=1e-6)
    assert bool(jnp.all(idx8[:3] == idx3))


def test_exit_confidence_is_valid_probability(rng):
    h = _rand(rng, (16, 64), jnp.bfloat16)
    w = _rand(rng, (64, 777), jnp.bfloat16)
    conf, idx = exit_confidence(h, w, interpret=True)
    assert bool(jnp.all(conf > 0)) and bool(jnp.all(conf <= 1.0))
    assert bool(jnp.all((idx >= 0) & (idx < 777)))


def test_ops_dispatch_xla_matches_interpret(rng):
    from repro.kernels import ops

    h = _rand(rng, (4, 64), jnp.bfloat16)
    w = _rand(rng, (64, 500), jnp.bfloat16)
    try:
        ops.set_backend("xla")
        c_x, i_x = ops.exit_confidence(h, w)
        ops.set_backend("pallas_interpret")
        c_p, i_p = ops.exit_confidence(h, w)
    finally:
        ops.set_backend("auto")
    np.testing.assert_allclose(np.asarray(c_x), np.asarray(c_p), atol=1e-3)
    assert bool(jnp.all(i_x == i_p))
