"""Differential suite for the PAGED slot-cache layout.

Cache-layout bugs are silent — a wrong block-table entry yields wrong
tokens, not crashes — so the paged data plane is held to bitwise equality
against two independent references on the same workload:

  * the dense slot layout (worst-case ``max_len`` arenas), and
  * the monolithic ``model.prefill`` + ``model.decode_step`` generator,

across block sizes (1, 3, 16), prefix sharing on/off, tight pools, and
randomized admission/retirement schedules.  Also covers the slot-layout
validation regression and the FifoBatcher / slot-ring edge cases.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.profiles import profile_from_arch
from repro.core.thresholds import synthetic_validation
from repro.core.topology import NetworkSpec, build_edge_network
from repro.core.types import DtoHyperParams
from repro.models import model as model_lib
from repro.serving import CollaborativeEngine, FifoBatcher, Request, monolithic_generate

GEN = 6
THRESHOLD = 0.35  # mixes early exits (mid-batch retirement) with full runs


def _build_engine(arch: str = "stablelm-1.6b", seed: int = 0, **reduced):
    cfg = get_config(arch).reduced(**reduced)
    params = model_lib.init_params(jax.random.key(0), cfg)
    profile = profile_from_arch(cfg)
    topo = build_edge_network(
        seed=seed, profile=profile, spec=NetworkSpec(num_eds=4, es_per_stage=(2, 2))
    )
    ep = synthetic_validation(seed=1, profile=profile)
    eng = CollaborativeEngine(
        params, cfg, topo, profile, ep, DtoHyperParams(rounds=20), seed=seed
    )
    eng.configuration_phase()
    eng.state.thresholds = np.full_like(eng.state.thresholds, THRESHOLD)
    return eng


@pytest.fixture(scope="module")
def engine():
    # small-but-real staged GQA model (the bench's shape)
    return _build_engine(
        vocab_size=128, d_model=64, d_ff=128, num_heads=2, num_kv_heads=2,
        head_dim=32,
    )


@pytest.fixture(scope="module")
def prompts():
    """Mixed lengths INCLUDING a shared 16-token prefix group — short
    prompts waste most of a dense arena (the memory paging reclaims) and the
    shared group exercises the prefix map."""
    rng = np.random.default_rng(2)
    common = rng.integers(0, 128, size=16).astype(np.int32)
    own = [
        np.concatenate([common, rng.integers(0, 128, size=n).astype(np.int32)])
        for n in (3, 5, 3)
    ]
    loose = [
        rng.integers(0, 128, size=length).astype(np.int32)
        for length in (24, 7, 12, 7, 18)
    ]
    return own + loose


@pytest.fixture(scope="module")
def reference(engine, prompts):
    """Monolithic single-host ground truth, per request."""
    return {
        i: (stage, tuple(toks))
        for i, p in enumerate(prompts)
        for toks, stage in [
            monolithic_generate(
                engine.programs.params, engine.cfg, p, engine.thresholds, GEN
            )
        ]
    }


def _serve(engine, prompts, seed=7, arrival_rate=1e5, batch_size=4, **kw):
    engine.rng = np.random.default_rng(seed)
    return engine.serve(
        prompts, arrival_rate=arrival_rate, batch_size=batch_size, gen_len=GEN, **kw
    )


# ---------------------------------------------------------------------------
# bitwise differential: paged == dense == monolithic
# ---------------------------------------------------------------------------


def test_dense_reference_matches_monolithic(engine, prompts, reference):
    stats = _serve(engine, prompts, decode_mode="cached")
    assert stats.sequences_by_rid() == reference


@pytest.mark.parametrize("block_size", [1, 3, 16])
@pytest.mark.parametrize("prefix_sharing", [True, False])
def test_paged_decode_bitwise_matches_references(
    engine, prompts, reference, block_size, prefix_sharing
):
    stats = _serve(
        engine,
        prompts,
        cache_layout="paged",
        block_size=block_size,
        prefix_sharing=prefix_sharing,
    )
    assert stats.sequences_by_rid() == reference
    assert len(stats.delays) == len(prompts)
    s = stats.summary()
    assert 0.0 < s["block_occupancy_peak"] <= 1.0
    if not prefix_sharing:
        assert s["prefix_hit_blocks"] == 0


@pytest.mark.parametrize(
    "seed,arrival_rate,num_slots",
    [(3, 40.0, 2), (11, 200.0, 3), (23, 1e5, 2)],
)
def test_paged_randomized_admission_retirement_schedules(
    engine, prompts, reference, seed, arrival_rate, num_slots
):
    """Random arrival processes against tiny slot rings: admission blocks on
    occupied slots, early exits retire rows mid-batch, freed slots re-admit
    waiting prompts — tokens must never change."""
    stats = _serve(
        engine,
        prompts,
        seed=seed,
        arrival_rate=arrival_rate,
        num_slots=num_slots,
        cache_layout="paged",
        block_size=3,
    )
    assert stats.sequences_by_rid() == reference


def test_paged_tight_pool_still_exact(engine, prompts, reference):
    """A pool far below the dense footprint (which would be
    num_slots * ceil(max_len / bs) = 4 * 8 blocks per replica) forces
    admission to wait on block frees; outputs must be unchanged."""
    stats = _serve(
        engine,
        prompts,
        cache_layout="paged",
        block_size=4,
        num_slots=4,
        num_blocks=16,
    )
    assert stats.sequences_by_rid() == reference
    assert stats.summary()["block_occupancy_peak"] <= 1.0


def test_paged_pool_too_small_raises_instead_of_stalling(engine, prompts):
    """A pool that cannot cover even one request's full generation must fail
    loudly, not hang or silently drop requests."""
    with pytest.raises(RuntimeError, match="block pool"):
        _serve(
            engine,
            prompts,
            cache_layout="paged",
            block_size=4,
            num_slots=2,
            num_blocks=4,
        )


def test_prefix_sharing_hits_and_shares_only_real_prefixes(
    engine, prompts, reference
):
    """The shared-prefix prompt group must produce prefix-map hits; block
    occupancy must not exceed the sharing-off run; outputs identical."""
    on = _serve(engine, prompts, cache_layout="paged", block_size=4)
    off = _serve(
        engine, prompts, cache_layout="paged", block_size=4, prefix_sharing=False
    )
    assert on.sequences_by_rid() == reference
    assert off.sequences_by_rid() == reference
    assert on.prefix_hit_blocks > 0
    assert off.prefix_hit_blocks == 0
    assert (
        on.summary()["block_occupancy_peak"] <= off.summary()["block_occupancy_peak"]
    )


def test_paged_mla_config_matches_dense():
    """Absorbed-latent MLA decode through block tables == dense slot rows."""
    eng = _build_engine("deepseek-v2-lite-16b", vocab_size=64)
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, 64, size=n).astype(np.int32) for n in (9, 5, 9)]
    dense = _serve(eng, prompts, batch_size=2)
    paged = _serve(eng, prompts, batch_size=2, cache_layout="paged", block_size=3)
    assert paged.sequences_by_rid() == dense.sequences_by_rid()
    assert len(paged.delays) == len(prompts)


def test_paged_rejects_stateless_mode(engine, prompts):
    with pytest.raises(ValueError, match="paged"):
        _serve(engine, prompts, cache_layout="paged", decode_mode="stateless")
    with pytest.raises(ValueError, match="cache_layout"):
        _serve(engine, prompts, cache_layout="blocked")


def test_block_copy_program_copies_every_pool_leaf(engine):
    """make_block_copy — the device half of allocator copy-on-write (unused
    by serve() today: engine sharing can never put an append into a shared
    block; kept for the preemption/fork follow-on)."""
    from repro.serving import steps

    cfg = engine.cfg
    pool, _ = model_lib.init_stage_paged_caches(
        cfg, 1, num_slots=2, num_blocks=4, block_size=4, max_len=8
    )
    rng = np.random.default_rng(0)
    pool = jax.tree.map(
        lambda a: jnp.asarray(rng.standard_normal(a.shape), a.dtype), pool
    )
    before = jax.tree.map(lambda a: np.asarray(a).copy(), pool)
    copy = steps.make_block_copy(cfg, 1)
    src = jnp.asarray([0, 2], jnp.int32)
    dst = jnp.asarray([3, 1], jnp.int32)
    out = copy(pool, src, dst)
    for d_new, d_old in zip(out, before):
        for key in d_old:
            new = np.asarray(d_new[key])
            np.testing.assert_array_equal(new[:, 3], d_old[key][:, 0])
            np.testing.assert_array_equal(new[:, 1], d_old[key][:, 2])
            np.testing.assert_array_equal(new[:, 0], d_old[key][:, 0])


# ---------------------------------------------------------------------------
# slot-layout validation (regression: actionable error, not mid-tree-map)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("init", ["dense", "paged"])
def test_sliding_window_slot_cache_error_is_actionable(init):
    """Window < max_len configs must be rejected up front by BOTH slot
    layouts with a ValueError naming the stage and the config field."""
    cfg = get_config("mixtral-8x7b").reduced(vocab_size=64)
    assert cfg.sliding_window is not None
    with pytest.raises(ValueError, match=r"stage 2 .*sliding_window=32"):
        if init == "dense":
            model_lib.init_stage_slot_caches(cfg, 2, 4, max_len=64)
        else:
            model_lib.init_stage_paged_caches(cfg, 2, 4, 8, 16, max_len=64)
    # window >= max_len is representable and must stay allowed
    model_lib.init_stage_slot_caches(cfg, 2, 2, max_len=cfg.sliding_window)


# ---------------------------------------------------------------------------
# FifoBatcher / slot-ring edge cases (PR 2 gaps)
# ---------------------------------------------------------------------------


def _req(rid):
    return Request(rid=rid, tokens=np.arange(3, dtype=np.int32), arrival=float(rid))


def test_fifo_batcher_drains_partial_and_respects_max_batches():
    b = FifoBatcher(batch_size=4)
    for rid in range(10):
        b.push(_req(rid))
    first = b.drain(max_batches=1)
    assert [r.rid for r in first[0]] == [0, 1, 2, 3]
    rest = b.drain()
    assert [len(batch) for batch in rest] == [4, 2]  # final batch is partial
    assert len(b) == 0 and b.drain() == []


def test_admission_waits_when_every_slot_is_occupied(engine, prompts, reference):
    """More live requests than slots: prompts must queue (not crash, not
    steal occupied slots) and be admitted as retirements free slots."""
    for layout in ("dense", "paged"):
        kw = {"cache_layout": layout}
        if layout == "paged":
            kw["block_size"] = 4
        stats = _serve(engine, prompts, num_slots=2, **kw)
        assert stats.sequences_by_rid() == reference
        assert len(stats.delays) == len(prompts)


def test_whole_batch_retires_in_one_step(engine, prompts):
    """threshold=0 exits every request at the first branch: entire batches
    retire in a single completion event, freeing all slots at once; slots
    must be reusable by the requests still queued behind them."""
    saved = engine.state.thresholds.copy()
    try:
        engine.state.thresholds = np.zeros_like(engine.state.thresholds)
        for layout in ("dense", "paged"):
            kw = {"cache_layout": layout}
            if layout == "paged":
                kw["block_size"] = 4
            stats = _serve(engine, prompts, num_slots=2, **kw)
            assert len(stats.delays) == len(prompts)
            first_exit = min(engine.cfg.exit_stages)
            assert set(stats.exit_stage) == {first_exit}
            assert all(len(toks) == 1 for toks in stats.gen_tokens)
    finally:
        engine.state.thresholds = saved


def test_num_slots_one_serializes_but_completes(engine, prompts, reference):
    """A single cache slot per replica degenerates to one-at-a-time decode;
    everything still completes with identical tokens."""
    for layout in ("dense", "paged"):
        kw = {"cache_layout": layout}
        if layout == "paged":
            kw["block_size"] = 4
        stats = _serve(engine, prompts, num_slots=1, **kw)
        assert stats.sequences_by_rid() == reference
        assert stats.peak_in_flight >= 1
