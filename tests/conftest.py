import numpy as np
import pytest

import jax

# Tests run on the single host CPU device (the dry-run's 512-device override
# lives ONLY in repro.launch.dryrun / subprocesses).
jax.config.update("jax_platform_name", "cpu")


@pytest.fixture
def rng():
    return np.random.default_rng(0)
