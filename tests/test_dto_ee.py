"""DTO-EE algorithm properties: Lemma 1 descent, convergence, beating
baselines, threshold coupling (Eqs. 17-18)."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need the dev extra
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.core import baselines, dto_ee, gradients, penalty, queueing
from repro.core.thresholds import synthetic_validation, threshold_step
from repro.core.topology import build_edge_network
from repro.core.types import DtoHyperParams, RESNET101_PROFILE

PROFILE = RESNET101_PROFILE


def _random_feasible_p(topo, rng):
    raw = rng.uniform(0.1, 1.0, topo.num_edges)
    sums = np.zeros(topo.num_nodes)
    np.add.at(sums, topo.edge_src, raw)
    return jnp.asarray(raw / sums[topo.edge_src], jnp.float32)


@given(seed=st.integers(0, 50))
@settings(max_examples=10, deadline=None)
def test_lemma1_eq19_is_descent_direction(seed):
    """<grad R(P), Gamma(P) - P> < 0 unless P is the fixed point (Lemma 1)."""
    rng = np.random.default_rng(seed)
    topo = build_edge_network(seed=seed % 5, profile=PROFILE, arrival_rate_scale=2.0)
    ep = synthetic_validation(seed=1, profile=PROFILE)
    I_node = jnp.asarray(ep.evaluate([0.7, 0.7]).stage_remaining, jnp.float32)[
        jnp.asarray(topo.node_stage)
    ]
    hyper = DtoHyperParams()
    p = _random_feasible_p(topo, rng)

    grad = jax.grad(lambda q: penalty.objective_r(q, topo, PROFILE, I_node, hyper))(p)
    phi, lam = queueing.steady_state_flows(p, topo, PROFILE, I_node)
    delta, _ = gradients.backward_recursion(p, topo, PROFILE, I_node, lam, hyper)
    p_next = dto_ee.eq19_update(p, delta, topo, hyper.tau_p)
    inner = float(jnp.sum(grad * (p_next - p)))
    moved = float(jnp.max(jnp.abs(p_next - p)))
    if moved > 1e-6:
        assert inner < 0.0


def test_objective_decreases_over_rounds():
    topo = build_edge_network(seed=0, profile=PROFILE, arrival_rate_scale=2.5)
    ep = synthetic_validation(seed=1, profile=PROFILE)
    hyper = DtoHyperParams(rounds=60)
    res = dto_ee.run_configuration_phase(
        topo, PROFILE, ep, hyper, adapt_thresholds=False
    )
    obj = res.objective_history
    assert obj[-1] < obj[0]
    # monotone up to small message-staleness jitter
    assert np.all(np.diff(obj) < 0.05 * obj[0])


def test_probabilities_stay_on_simplex():
    topo = build_edge_network(seed=2, profile=PROFILE, arrival_rate_scale=2.0)
    ep = synthetic_validation(seed=1, profile=PROFILE)
    res = dto_ee.run_configuration_phase(topo, PROFILE, ep, DtoHyperParams())
    p = np.asarray(res.state.carry.p)
    assert np.all(p >= -1e-6) and np.all(p <= 1 + 1e-6)
    sums = np.zeros(topo.num_nodes)
    np.add.at(sums, topo.edge_src, p)
    senders = np.unique(topo.edge_src)
    np.testing.assert_allclose(sums[senders], 1.0, atol=1e-4)


@pytest.mark.parametrize("seed", [0, 3])
def test_dto_ee_beats_static_baselines(seed):
    """Analytic T of converged DTO-EE <= CF and BF on the same thresholds."""
    topo = build_edge_network(seed=seed, profile=PROFILE, arrival_rate_scale=2.5)
    ep = synthetic_validation(seed=1, profile=PROFILE)
    hyper = DtoHyperParams()
    res = dto_ee.solve(topo, PROFILE, ep, hyper, adapt_thresholds=False)
    I_node = jnp.asarray(res.state.stage_remaining, jnp.float32)[
        jnp.asarray(topo.node_stage)
    ]
    t_dto, _, stable = dto_ee.evaluate_strategy(
        res.state.carry.p, topo, PROFILE, I_node, hyper
    )
    assert stable
    for p_b in (baselines.computing_first(topo), baselines.bandwidth_first(topo)):
        t_b, _, _ = dto_ee.evaluate_strategy(p_b, topo, PROFILE, I_node, hyper)
        assert t_dto < t_b


def test_threshold_step_only_moves_when_utility_improves():
    topo = build_edge_network(seed=0, profile=PROFILE, arrival_rate_scale=2.0)
    ep = synthetic_validation(seed=1, profile=PROFILE)
    hyper = DtoHyperParams()
    thresholds = np.array([0.8, 0.8])
    p = dto_ee.uniform_strategy(topo)
    I_node = jnp.asarray(ep.evaluate(thresholds).stage_remaining, jnp.float32)[
        jnp.asarray(topo.node_stage)
    ]
    phi, lam = queueing.steady_state_flows(p, topo, PROFILE, I_node)
    _, omega = gradients.backward_recursion(p, topo, PROFILE, I_node, lam, hyper)
    nodes = topo.nodes_at_stage(ep.branch_stage[0])
    dec = threshold_step(
        ep,
        thresholds,
        0,
        np.asarray(phi)[nodes],
        np.asarray(omega)[nodes],
        float(topo.phi_ext.sum()),
        hyper,
    )
    if dec.changed:
        assert dec.delta_u < 0.0
        assert abs(dec.thresholds[0] - thresholds[0]) == pytest.approx(hyper.tau_c)
    else:
        assert np.array_equal(dec.thresholds, thresholds)


def test_warm_start_helps_after_perturbation():
    """After a small environment change, warm-started DTO-EE recovers in one
    phase to an objective no worse than a cold start gets in one phase."""
    from repro.core.topology import with_capacity_scale

    topo = build_edge_network(seed=1, profile=PROFILE, arrival_rate_scale=2.0)
    ep = synthetic_validation(seed=1, profile=PROFILE)
    hyper = DtoHyperParams(rounds=30)
    warm = dto_ee.run_configuration_phase(topo, PROFILE, ep, hyper).state

    topo2 = with_capacity_scale(topo, 0.9)
    res_warm = dto_ee.run_configuration_phase(
        topo2, PROFILE, ep, hyper, state=warm
    )
    res_cold = dto_ee.run_configuration_phase(topo2, PROFILE, ep, hyper)
    assert (
        res_warm.objective_history[-1]
        <= res_cold.objective_history[-1] * 1.05
    )
