"""Serving engine + steps: end-to-end on a tiny real model."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.profiles import profile_from_arch, stage_param_counts
from repro.core.thresholds import synthetic_validation
from repro.core.topology import build_edge_network, NetworkSpec
from repro.core.types import DtoHyperParams
from repro.models import model as model_lib
from repro.serving import CollaborativeEngine, select_exit
from repro.serving.batching import FifoBatcher, Request, pad_tokens


@pytest.fixture(scope="module")
def engine():
    cfg = get_config("stablelm-1.6b").reduced(vocab_size=128)
    params = model_lib.init_params(jax.random.key(0), cfg)
    profile = profile_from_arch(cfg)
    topo = build_edge_network(
        seed=0, profile=profile, spec=NetworkSpec(num_eds=4, es_per_stage=(2, 2))
    )
    ep = synthetic_validation(seed=1, profile=profile)
    return CollaborativeEngine(
        params, cfg, topo, profile, ep, DtoHyperParams(rounds=20), seed=0
    )


def _prompts(n, vocab=128, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, vocab, size=12).astype(np.int32) for _ in range(n)]


def test_engine_completes_all_requests(engine):
    engine.configuration_phase()
    stats = engine.serve(_prompts(8), duration=1.0)
    s = stats.summary()
    assert s["num_completed"] == 8
    assert np.isfinite(s["mean_delay"])
    assert all(t >= 0 for t in stats.tokens)


def test_threshold_zero_exits_at_first_branch(engine):
    engine.state.thresholds = np.zeros_like(engine.state.thresholds)
    stats = engine.serve(_prompts(6), duration=1.0)
    first_exit = engine.exit_profile.branch_stage[0]
    assert all(s == first_exit for s in stats.exit_stage)


def test_threshold_above_one_never_exits_early(engine):
    engine.state.thresholds = np.full_like(engine.state.thresholds, 1.01)
    stats = engine.serve(_prompts(6), duration=1.0)
    H = engine.profile.num_stages
    assert all(s == H for s in stats.exit_stage)


# ---------------------------------------------------------------------------
# select_exit (the fused serve-step rule)
# ---------------------------------------------------------------------------


def test_select_exit_first_confident_branch_wins():
    next_token = jnp.asarray([7, 8, 9], jnp.int32)
    conf = jnp.asarray([[0.9, 0.1], [0.2, 0.95], [0.1, 0.2]])
    toks = jnp.asarray([[1, 2], [3, 4], [5, 6]], jnp.int32)
    thr = jnp.asarray([0.8, 0.8])
    tok, stage = select_exit(next_token, conf, toks, thr)
    assert tok.tolist() == [1, 4, 9]
    assert stage.tolist() == [0, 1, 2]  # 2 == n_exits == final head


def test_select_exit_no_branches():
    next_token = jnp.asarray([3], jnp.int32)
    tok, stage = select_exit(
        next_token, jnp.zeros((1, 0)), jnp.zeros((1, 0), jnp.int32), jnp.zeros((0,))
    )
    assert tok.tolist() == [3]


# ---------------------------------------------------------------------------
# batching utilities
# ---------------------------------------------------------------------------


def test_fifo_batcher_drains_in_order():
    b = FifoBatcher(batch_size=3)
    for i in range(7):
        b.push(Request(rid=i, tokens=np.arange(4), arrival=float(i)))
    batches = b.drain()
    assert [len(x) for x in batches] == [3, 3, 1]
    assert [r.rid for r in batches[0]] == [0, 1, 2]
    assert len(b) == 0


def test_pad_tokens():
    reqs = [
        Request(rid=0, tokens=np.array([1, 2, 3]), arrival=0.0),
        Request(rid=1, tokens=np.array([4]), arrival=0.0),
    ]
    out, lengths = pad_tokens(reqs)
    assert out.shape == (2, 3)
    assert lengths.tolist() == [3, 1]
    assert out[1].tolist() == [4, 0, 0]


def test_stage_param_counts_sum_close_to_total():
    cfg = get_config("glm4-9b")
    stages = sum(stage_param_counts(cfg))
    total = cfg.param_count()
    # embed + lm_head excluded from stage counts
    non_stage = 2 * cfg.vocab_size * cfg.d_model
    assert abs(stages - (total - non_stage)) / total < 0.02
