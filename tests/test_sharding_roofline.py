"""Sharding specs + roofline HLO parsing."""
import re

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro import sharding
from repro.configs import get_config
from repro.models import model as model_lib
from repro.roofline.hlo import collective_stats
from repro.roofline import analysis, constants


@pytest.fixture
def mesh11():
    return jax.make_mesh((1, 1), ("data", "model"))


def _abstract_mesh(sizes, names):
    """AbstractMesh across the jax signature change (positional axis_sizes +
    axis_names vs. a single tuple of (name, size) pairs)."""
    from jax.sharding import AbstractMesh

    try:
        return AbstractMesh(sizes, names)
    except TypeError:
        return AbstractMesh(tuple(zip(names, sizes)))


def test_constrain_is_noop_without_mesh():
    sharding.clear_mesh()
    x = jnp.ones((4, 4))
    y = sharding.constrain(x, "batch", "seq")
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_param_specs_layouts(mesh11):
    rules = sharding.set_mesh(mesh11)
    cfg = get_config("stablelm-1.6b").reduced()
    aparams = model_lib.abstract_params(cfg)
    specs = sharding.param_specs(aparams)
    flat = jax.tree_util.tree_flatten_with_path(specs)[0]
    # every leaf got a PartitionSpec; stacked stage weights lead with None
    for path, spec in flat:
        pstr = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        assert isinstance(spec, P)
        if "stages" in pstr and len(spec) >= 1:
            assert spec[0] is None, f"{pstr} must not shard the scan dim"
    sharding.clear_mesh()


def test_cache_specs_shard_seq_on_model_axis():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    sharding.set_mesh(mesh)
    cfg = get_config("glm4-9b").reduced()
    caches = model_lib.cache_specs(cfg, batch=2, max_len=64)
    specs = sharding.cache_specs(caches)
    # with axis sizes 1 everything degrades to replication but specs exist
    for leaf in jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)):
        assert isinstance(leaf, P)
    sharding.clear_mesh()


def test_divisibility_fallback():
    from repro.sharding.specs import MeshRules, _spec_for

    mesh = _abstract_mesh((4, 2), ("data", "model"))
    rules = MeshRules.standard(mesh)
    # dim 7 not divisible by 4 / dim 3 not divisible by 2 -> replicated
    assert _spec_for((7, 3), ("batch", "seq"), rules) == P(None, None)
    # divisible dims shard
    assert _spec_for((8, 4), ("batch", "seq"), rules) == P("data", "model")


# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------

FAKE_HLO = """
  %all-reduce.1 = f32[16,1024]{1,0} all-reduce(%x), replica_groups={{0,1,2,3}}, to_apply=%add
  %all-gather.2 = bf16[4,512]{1,0} all-gather(%y), replica_groups=[2,8]<=[16], dimensions={0}
  %reduce-scatter.3 = f32[128]{0} reduce-scatter(%z), replica_groups={{0,1}}, to_apply=%add
  %collective-permute.4 = bf16[64,64]{1,0} collective-permute(%w), source_target_pairs={{0,1}}
  %all-reduce.5 = f32[8]{0} all-reduce(%v), replica_groups={{0}}, to_apply=%add
"""


def test_collective_stats_parses_ops():
    st = collective_stats(FAKE_HLO, num_devices=16)
    assert st.counts["all-reduce"] == 1  # groups of 1 skipped
    assert st.counts["all-gather"] == 1
    assert st.counts["reduce-scatter"] == 1
    assert st.counts["collective-permute"] == 1
    # all-reduce: 2 * bytes * 3/4
    ar = 16 * 1024 * 4 * 2 * 3 / 4
    assert abs(st.by_op["all-reduce"] - ar) < 1
    # all-gather (iota groups of 8): bytes * 7/8
    ag = 4 * 512 * 2 * 7 / 8
    assert abs(st.by_op["all-gather"] - ag) < 1
    assert st.global_bytes == pytest.approx(st.per_device_bytes * 16)


def test_roofline_report_terms():
    rep = analysis.RooflineReport(
        arch="a",
        shape="train_4k",
        mesh="m",
        num_devices=256,
        hlo_flops=1e18,
        hlo_bytes=1e15,
        collective=collective_stats(FAKE_HLO, 256),
        model_flops=5e17,
        compute_s=1e18 / (256 * constants.PEAK_FLOPS_BF16),
        memory_s=1e15 / (256 * constants.HBM_BW),
        collective_s=1.0,
    )
    assert rep.dominant == "compute"  # 19.8s compute > 1s collective
    assert 0 < rep.useful_flops_ratio <= 1
    assert rep.roofline_fraction < 1


def test_model_flops_modes():
    from repro.configs import SHAPES

    cfg = get_config("stablelm-1.6b")
    n = cfg.param_count()
    train = analysis.model_flops_for(cfg, SHAPES["train_4k"])
    assert train == pytest.approx(6.0 * n * 4096 * 256, rel=1e-6)
    dec = analysis.model_flops_for(cfg, SHAPES["decode_32k"])
    assert dec == pytest.approx(2.0 * n * 128, rel=1e-6)


def test_pure_dp_policy_maps_all_axes_to_batch():
    from repro.sharding.specs import MeshRules

    mesh = _abstract_mesh((2, 4, 4), ("pod", "data", "model"))
    rules = MeshRules.pure_dp(mesh)
    assert rules.batch_axes == ("pod", "data", "model")
    assert rules.tp_axis is None
    assert rules.axis_size(rules.batch_axes) == 32


def test_cache_feature_sharding_avoids_seq_dim(monkeypatch):
    """Default KV policy shards the feature dim (local per-token writes);
    REPRO_CACHE_SHARD=seq restores the sequence layout."""
    from repro.sharding import specs as S

    mesh = _abstract_mesh((4, 4), ("data", "model"))
    rules = S.MeshRules.standard(mesh)
    cache = {
        "k": jax.ShapeDtypeStruct((2, 8, 64, 8, 128), jnp.bfloat16),
        "v": jax.ShapeDtypeStruct((2, 8, 64, 8, 128), jnp.bfloat16),
        "pos": jax.ShapeDtypeStruct((2,), jnp.int32),
    }
    monkeypatch.setenv("REPRO_CACHE_SHARD", "feature")
    spec = S.cache_specs(cache, rules)["k"]
    assert spec == P(None, "data", None, None, "model")  # hd sharded, seq local
    monkeypatch.setenv("REPRO_CACHE_SHARD", "seq")
    spec = S.cache_specs(cache, rules)["k"]
    assert spec == P(None, "data", "model", None, None)  # seq sharded


def test_constrain_like_params_noop_without_mesh():
    sharding.clear_mesh()
    tree = {"stages": [{"w_q": jnp.ones((4, 4))}]}
    out = sharding.specs.constrain_like_params(tree) if hasattr(sharding, "specs") else tree
    from repro.sharding.specs import constrain_like_params

    out = constrain_like_params(tree)
    np.testing.assert_array_equal(
        np.asarray(out["stages"][0]["w_q"]), np.ones((4, 4))
    )
