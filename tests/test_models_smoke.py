"""Per-arch smoke tests: reduced config, one forward/train step on CPU,
output shapes + finiteness; prefill/decode consistency."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config, list_archs
from repro.models import model as model_lib

ARCHS = list_archs()


def _batch(cfg, B=2, S=32, seed=0):
    rng = np.random.default_rng(seed)
    labels = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    if cfg.frontend == "embeds":
        x = jnp.asarray(rng.standard_normal((B, S, cfg.d_model)) * 0.1, jnp.bfloat16)
        return {"embeds": x, "labels": labels}
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    return {"tokens": toks, "labels": labels}


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_shapes_and_finiteness(arch):
    cfg = get_config(arch).reduced()
    params = model_lib.init_params(jax.random.key(0), cfg)
    batch = _batch(cfg)
    loss, metrics = model_lib.loss_fn(params, batch, cfg)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss))
    grads = jax.grad(lambda p: model_lib.loss_fn(p, batch, cfg)[0])(params)
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_then_decode(arch):
    cfg = get_config(arch).reduced()
    params = model_lib.init_params(jax.random.key(0), cfg)
    B, S = 2, 32
    batch = {k: v for k, v in _batch(cfg, B, S).items() if k != "labels"}
    tok, conf, etok, caches = model_lib.prefill(params, batch, cfg, max_len=S + 8)
    assert tok.shape == (B,)
    n_exits = len(cfg.exit_stages)
    assert conf.shape == (B, n_exits)
    assert bool(jnp.all((conf >= 0) & (conf <= 1)))

    if cfg.frontend == "embeds":
        db = {"embeds": jnp.zeros((B, 1, cfg.d_model), jnp.bfloat16)}
    else:
        db = {"tokens": tok[:, None]}
    tok2, conf2, etok2, caches2 = model_lib.decode_step(params, db, caches, cfg)
    assert tok2.shape == (B,)
    assert bool(jnp.all(jnp.isfinite(conf2)))
    # cache positions advanced
    flat1 = jax.tree_util.tree_flatten_with_path(caches)[0]
    flat2 = jax.tree_util.tree_flatten_with_path(caches2)[0]
    pos1 = [l for p, l in flat1 if getattr(p[-1], "key", None) == "pos"]
    pos2 = [l for p, l in flat2 if getattr(p[-1], "key", None) == "pos"]
    for a, b in zip(pos1, pos2):
        assert bool(jnp.all(b == a + 1))


def test_decode_matches_full_forward_dense():
    """Greedy decode token == argmax of a full forward on the extended
    sequence (position-exact cache correctness) for a dense GQA arch."""
    cfg = get_config("stablelm-1.6b").reduced()
    params = model_lib.init_params(jax.random.key(1), cfg)
    rng = np.random.default_rng(0)
    B, S = 1, 16
    toks = rng.integers(0, cfg.vocab_size, (B, S + 1)).astype(np.int32)

    tok_a, _, _, caches = model_lib.prefill(
        params, {"tokens": jnp.asarray(toks[:, :S])}, cfg, max_len=S + 4
    )
    tok_b, _, _, _ = model_lib.decode_step(
        params, {"tokens": jnp.asarray(toks[:, S : S + 1])}, caches, cfg
    )
    # oracle: full forward over S+1 tokens
    x, exits, _ = model_lib.forward_hidden(
        params, {"tokens": jnp.asarray(toks)}, cfg
    )
    from repro.models import layers

    h = layers.apply_norm(cfg.norm, params["final_norm"], x[:, -1:])
    logits = model_lib.lm_logits(params, h, cfg)[:, 0]
    oracle = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    assert bool(jnp.all(tok_b == oracle))


def test_param_counts_match_claimed_scale():
    """Full configs land near their nameplate sizes."""
    expect = {
        "qwen2.5-32b": (31e9, 34e9),
        "mixtral-8x7b": (45e9, 48e9),  # total (not active)
        "glm4-9b": (8e9, 10.5e9),
        "stablelm-1.6b": (1.4e9, 1.9e9),
        "internlm2-20b": (18e9, 21e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo < n < hi, f"{arch}: {n/1e9:.2f}B outside [{lo/1e9}, {hi/1e9}]"


def test_loss_fn_deep_supervision_exits_present():
    cfg = get_config("glm4-9b").reduced()
    params = model_lib.init_params(jax.random.key(0), cfg)
    _, metrics = model_lib.loss_fn(params, _batch(cfg), cfg)
    for h in cfg.exit_stages:
        assert f"exit_{h}_loss" in metrics
