"""Online control plane: reconfiguration invariants, telemetry estimators,
live-environment scenarios, threshold-aware packing, simulator coalescing."""
import dataclasses

import numpy as np
import pytest

import jax

from repro.configs import get_config
from repro.control import (
    ControllerConfig,
    ReconfigController,
    Scenario,
    ScenarioEvent,
    Telemetry,
    TelemetryConfig,
    arrival_burst,
    busiest_replica,
    get_scenario,
    node_slowdown,
)
from repro.core.profiles import profile_from_arch
from repro.core.thresholds import synthetic_validation
from repro.core.topology import NetworkSpec, build_edge_network, with_link_degradation
from repro.core.types import DtoHyperParams
from repro.models import model as model_lib
from repro.serving import CollaborativeEngine
from repro.serving.batching import (
    ExitPredictor,
    Request,
    pack_decode_batch,
    pow2_floor,
)

THRESHOLD = 0.1


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("stablelm-1.6b").reduced(
        vocab_size=128, d_model=64, d_ff=128, num_heads=2, num_kv_heads=2,
        head_dim=32,
    )
    params = model_lib.init_params(jax.random.key(0), cfg)
    profile = profile_from_arch(cfg)
    topo = build_edge_network(
        seed=0,
        profile=profile,
        spec=NetworkSpec(num_eds=4, es_per_stage=(2, 3)),
        capacity_scale=0.005,  # paper-like ~10-50 ms stage service times
    )
    ep = synthetic_validation(seed=1, profile=profile)
    return cfg, params, profile, topo, ep


def make_engine(setup, seed=0):
    cfg, params, profile, topo, ep = setup
    eng = CollaborativeEngine(
        params, cfg, topo, profile, ep, DtoHyperParams(rounds=20), seed=seed
    )
    eng.configuration_phase()
    eng.state.thresholds = np.full_like(eng.state.thresholds, THRESHOLD)
    return eng


@pytest.fixture(scope="module")
def prompts():
    rng = np.random.default_rng(2)
    return [rng.integers(0, 128, size=12).astype(np.int32) for _ in range(12)]


def _serve(eng, prompts, seed=7, **kw):
    eng.rng = np.random.default_rng(seed)
    kw.setdefault("arrival_rate", 60.0)
    kw.setdefault("batch_size", 4)
    return eng.serve(prompts, **kw)


def _noop_controller(eng):
    """A controller that always plans and installs a ZERO-round phase: the
    installed p / thresholds are bitwise the live ones."""
    tele = Telemetry(eng.topo, TelemetryConfig(window_s=0.1))
    return ReconfigController(
        tele,
        ControllerConfig(
            interval=0.03, rounds=0, drift_deadband=-1.0, p_deadband=-1.0
        ),
    )


# ---------------------------------------------------------------------------
# reconfiguration invariants (satellite: update_topology warm-start)
# ---------------------------------------------------------------------------


def test_noop_reconfig_install_is_bitwise_invisible(setup, prompts):
    """Mid-serve installs whose p/thresholds are unchanged must leave every
    in-flight request bitwise identical to an uninterrupted run — tokens,
    exits, and even delays."""
    ref_eng = make_engine(setup)
    ref = _serve(ref_eng, prompts, gen_len=3, decode_mode="cached")
    eng = make_engine(setup)
    ctrl = _noop_controller(eng)
    stats = _serve(
        eng, prompts, gen_len=3, decode_mode="cached", controller=ctrl
    )
    assert stats.num_reconfigs > 0  # the install path genuinely ran
    assert stats.sequences_by_rid() == ref.sequences_by_rid()
    assert stats.exit_stage == ref.exit_stage
    np.testing.assert_array_equal(stats.delays, ref.delays)


def test_update_topology_noop_swap_preserves_stream(setup, prompts):
    ref = _serve(make_engine(setup), prompts)
    eng = make_engine(setup)
    eng.update_topology(dataclasses.replace(eng.topo))
    stats = _serve(eng, prompts)
    assert stats.sequences_by_rid() == ref.sequences_by_rid()
    np.testing.assert_array_equal(stats.delays, ref.delays)


def test_update_topology_rejects_edge_set_change(setup):
    from repro.core.topology import with_node_failure

    eng = make_engine(setup)
    victim = int(eng.topo.nodes_at_stage(1)[0])
    broken = with_node_failure(eng.topo, victim)
    with pytest.raises(ValueError):
        eng.update_topology(broken)


def test_configuration_phase_adapt_false_never_moves_thresholds(setup):
    eng = make_engine(setup)
    before = eng.state.thresholds.copy()
    for _ in range(3):
        eng.configuration_phase(adapt_thresholds=False)
        np.testing.assert_array_equal(eng.state.thresholds, before)


def test_controller_adapt_false_never_moves_thresholds(setup):
    eng = make_engine(setup)
    tele = Telemetry(eng.topo)
    ctrl = ReconfigController(
        tele,
        ControllerConfig(
            rounds=10, drift_deadband=-1.0, p_deadband=-1.0,
            adapt_thresholds=False,
        ),
    )
    plan = ctrl.plan(eng, now=1.0)
    assert plan is not None
    np.testing.assert_array_equal(plan.state.thresholds, eng.state.thresholds)


def test_controller_hysteresis_skips_quiet_environment(setup):
    eng = make_engine(setup)
    tele = Telemetry(eng.topo)  # no observations: effective == view
    ctrl = ReconfigController(tele, ControllerConfig(drift_deadband=0.05))
    assert ctrl.plan(eng, now=1.0) is None
    assert ctrl.log[-1]["action"] == "skip"


# ---------------------------------------------------------------------------
# telemetry estimators
# ---------------------------------------------------------------------------


def test_telemetry_mu_estimate_tracks_throttled_replica(setup):
    _, _, _, topo, _ = setup
    tele = Telemetry(topo, TelemetryConfig(window_s=1.0))
    node = int(topo.nodes_at_stage(1)[0])
    true_mu = float(topo.mu[node]) * 0.1  # throttled to 10%
    for k in range(30):
        tele.on_batch(0.01 * k, node, gflops=true_mu * 0.01, wall=0.01, queue_depth=2)
    mu = tele.mu_estimates(topo, now=0.3)
    assert mu[node] == pytest.approx(true_mu, rel=0.05)
    other = int(topo.nodes_at_stage(1)[1])
    assert mu[other] == topo.mu[other]  # unobserved: view value


def test_telemetry_arrival_window_evicts(setup):
    _, _, _, topo, _ = setup
    tele = Telemetry(topo, TelemetryConfig(window_s=1.0))
    ed = int(topo.nodes_at_stage(0)[0])
    for k in range(10):
        tele.on_arrival(0.1 * k, ed)  # 10 arrivals in [0, 1)
    phi = tele.arrival_rates(topo, now=1.0)
    assert phi[ed] == pytest.approx(10.0, rel=0.01)
    # 5 seconds later every one of them has left the window
    phi_late = tele.arrival_rates(topo, now=6.0)
    assert phi_late[ed] == 0.0


def test_telemetry_effective_topology_substitutes_measurements(setup):
    _, _, _, topo, _ = setup
    tele = Telemetry(topo, TelemetryConfig(window_s=1.0))
    e = 0
    src, dst = int(topo.edge_src[e]), int(topo.edge_dst[e])
    # 1.0 MB charged 0.5 s of hop time -> 2 MB/s (wall passed explicitly:
    # the stream's t0/t1 delimit the span, wall is the modeled hop time)
    tele.on_transfer(-0.4, 0.1, 0.5, src, dst, mb=1.0)
    eff = tele.effective_topology(topo, now=0.2)
    assert eff.edge_rate[e] == pytest.approx(2.0)
    # untouched edges keep the view's rates
    np.testing.assert_array_equal(eff.edge_rate[1:], topo.edge_rate[1:])
    eff.validate()


def test_telemetry_exit_fractions(setup):
    _, _, _, topo, _ = setup
    tele = Telemetry(topo, TelemetryConfig(window_s=10.0))
    for rid, stage in enumerate((2, 2, 2, 4)):
        tele.on_exit(0.5, rid, stage)
    frac = tele.exit_fractions(now=1.0)
    assert frac[2] == pytest.approx(0.75)
    assert frac[4] == pytest.approx(0.25)


def test_straggler_estimates_surface_in_summary(setup, prompts):
    eng = make_engine(setup)
    stats = _serve(eng, prompts)
    caps = stats.summary()["capacity_estimates"]
    es = [int(v) for v in np.nonzero(eng.topo.node_stage > 0)[0]]
    assert set(caps) == set(es)
    # every replica that served work has a finite positive estimate near
    # nameplate (no scenario: the environment IS the view)
    for v, mu_hat in caps.items():
        assert 0 < mu_hat < float("inf")


# ---------------------------------------------------------------------------
# scenarios
# ---------------------------------------------------------------------------


def test_slowdown_scenario_detected_and_reconfigured(setup, prompts):
    eng = make_engine(setup)
    victim = busiest_replica(eng.topo, eng.p)
    span = len(prompts) / 60.0
    scn = node_slowdown(eng.topo, 0.1 * span, 10 * span, factor=0.1, node=victim)
    tele = Telemetry(eng.topo, TelemetryConfig(window_s=span / 6))
    ctrl = ReconfigController(
        tele, ControllerConfig(interval=span / 6, rounds=5, drift_deadband=0.2)
    )
    stats = _serve(eng, prompts, scenario=scn, controller=ctrl)
    assert len(stats.delays) == len(prompts)
    assert stats.num_reconfigs >= 1
    caps = stats.summary()["capacity_estimates"]
    # the straggler saw the throttle...
    assert caps[victim] < 0.5 * float(eng.topo.mu[victim]) or caps[
        victim
    ] < 0.5 * float(eng.straggler.mu_hat[victim] / 0.1)
    # ...but the optimizer's view was never mutated directly by the scenario
    assert float(eng.topo.mu[victim]) > 0


def test_scenario_view_isolation(setup, prompts):
    """Scenario mutations hit a private copy: self.topo is untouched."""
    eng = make_engine(setup)
    mu_before = eng.topo.mu.copy()
    span = len(prompts) / 60.0
    scn = node_slowdown(eng.topo, 0.05 * span, 10 * span, factor=0.2, p=eng.p)
    _serve(eng, prompts, scenario=scn)
    np.testing.assert_array_equal(eng.topo.mu, mu_before)


def test_failure_scenario_reroutes_and_completes(setup, prompts):
    eng = make_engine(setup)
    span = len(prompts) / 60.0
    scn = get_scenario("failure", eng.topo, p=eng.p, horizon=span)
    dead = scn.events[0].node
    stats = _serve(eng, prompts, scenario=scn)
    assert len(stats.delays) == len(prompts)  # nobody lost
    assert dead not in set(eng.topo.edge_dst.tolist())  # view dropped it
    # surviving strategy still sums to 1 per source
    sums = np.zeros(eng.topo.num_nodes)
    np.add.at(sums, eng.topo.edge_src, eng.p)
    senders = np.unique(eng.topo.edge_src)
    np.testing.assert_allclose(sums[senders], 1.0, atol=1e-6)


def test_failure_scenario_rejected_for_cached_decode(setup, prompts):
    eng = make_engine(setup)
    scn = get_scenario("failure", eng.topo, p=eng.p, horizon=1.0)
    with pytest.raises(ValueError):
        _serve(eng, prompts, gen_len=3, decode_mode="cached", scenario=scn)


def test_burst_scenario_modulates_arrivals(setup, prompts):
    _, _, _, topo, _ = setup
    scn = arrival_burst(topo, 1.0, 2.0, factor=4.0, ed_share=0.5, seed=0)
    assert scn.modulates_arrivals and scn.modulates_eds
    assert scn.arrival_factor(0.5) == 1.0
    assert scn.arrival_factor(1.5) > 1.0
    assert scn.arrival_factor(2.5) == 1.0
    eng = make_engine(setup)
    stats = _serve(eng, prompts, scenario=scn)
    assert len(stats.delays) == len(prompts)


def test_link_degradation_helper_scales_named_pairs(setup):
    _, _, _, topo, _ = setup
    pair = (int(topo.edge_src[3]), int(topo.edge_dst[3]))
    out = with_link_degradation(topo, [pair, (999, 999)], 0.5)
    assert out.edge_rate[3] == pytest.approx(topo.edge_rate[3] * 0.5)
    untouched = np.ones(topo.num_edges, bool)
    for i, (s, d) in enumerate(zip(topo.edge_src, topo.edge_dst)):
        if (int(s), int(d)) == pair:
            untouched[i] = False
    np.testing.assert_array_equal(
        out.edge_rate[untouched], topo.edge_rate[untouched]
    )


def test_scenario_event_apply_env_in_place(setup):
    _, _, _, topo, _ = setup
    env = dataclasses.replace(
        topo, mu=topo.mu.copy(), phi_ext=topo.phi_ext.copy(),
        edge_rate=topo.edge_rate.copy(),
    )
    scn = Scenario(name="x")
    node = int(topo.nodes_at_stage(1)[0])
    scn.apply_env(ScenarioEvent(0.0, "mu_scale", node=node, factor=0.5), env)
    assert env.mu[node] == pytest.approx(topo.mu[node] * 0.5)
    with pytest.raises(ValueError):
        scn.apply_env(ScenarioEvent(0.0, "fail", node=node), env)


# ---------------------------------------------------------------------------
# threshold-aware batch packing
# ---------------------------------------------------------------------------


def test_pow2_floor():
    assert [pow2_floor(n) for n in (1, 2, 3, 4, 5, 7, 8, 9)] == [
        1, 2, 2, 4, 4, 4, 8, 8,
    ]
    with pytest.raises(ValueError):
        pow2_floor(0)


def _mk_req(rid, cls_conf=None, generated=0):
    r = Request(rid=rid, tokens=np.arange(4), arrival=float(rid))
    if cls_conf is not None:
        r.last_conf[0] = cls_conf
    r.generated = [1] * generated
    return r


def test_pack_decode_batch_groups_head_class_and_trims():
    thr = np.asarray([0.5])
    classify = ExitPredictor(lambda: thr, gen_len=8)
    # head predicted to exit (conf near threshold); rows 2 and 4 match it
    items = [
        (0, _mk_req(0, cls_conf=0.6)),
        (1, _mk_req(1, cls_conf=0.01, generated=1)),
        (2, _mk_req(2, cls_conf=0.55)),
        (3, _mk_req(3, cls_conf=0.02, generated=1)),
        (4, _mk_req(4, cls_conf=0.9)),
    ]
    take, rest = pack_decode_batch(items, batch_size=4, classify=classify)
    # 5 candidates -> cand [0,2,4,1] -> pow2 trim to 4: head class first
    assert [it[0] for it in take] == [0, 2, 4, 1]
    assert [it[0] for it in rest] == [3]
    # fewer rows than batch_size: trim to the exact padded shape
    take, rest = pack_decode_batch(items[:3], batch_size=8, classify=classify)
    assert len(take) == 2  # pow2_floor(3)
    assert [it[0] for it in rest] == [1]  # non-head-class row bumped


def test_pack_decode_batch_head_never_starves():
    classify = ExitPredictor(lambda: np.asarray([0.5]), gen_len=8)
    items = [(i, _mk_req(i, cls_conf=0.01, generated=i % 3)) for i in range(6)]
    take, _ = pack_decode_batch(items, batch_size=4, classify=classify)
    assert take[0][0] == 0


def test_threshold_policy_token_identical_and_no_extra_padding(setup):
    eng = make_engine(setup)
    rng = np.random.default_rng(5)
    prompts = [
        rng.integers(0, 128, size=int(rng.integers(8, 24))).astype(np.int32)
        for _ in range(24)
    ]
    out = {}
    for policy in ("fifo", "threshold"):
        eng.rng = np.random.default_rng(11)
        stats = eng.serve(
            prompts,
            arrival_rate=1e6,
            batch_size=8,
            gen_len=8,
            decode_mode="cached",
            num_slots=8,
            batch_policy=policy,
        )
        out[policy] = (stats.sequences_by_rid(), stats.summary()["padded_row_frac"])
    assert out["fifo"][0] == out["threshold"][0]
    assert out["threshold"][1] <= out["fifo"][1]


def test_bad_batch_policy_rejected(setup, prompts):
    eng = make_engine(setup)
    with pytest.raises(ValueError):
        _serve(eng, prompts, batch_policy="lifo")


# ---------------------------------------------------------------------------
# simulator same-timestamp harvest
# ---------------------------------------------------------------------------


def test_simulator_coalesce_results_identical():
    from repro.core import dto_ee, simulator
    from repro.core.types import RESNET101_PROFILE

    profile = RESNET101_PROFILE
    topo = build_edge_network(seed=0, profile=profile, arrival_rate_scale=5.0)
    ep = synthetic_validation(seed=1, profile=profile)
    res = dto_ee.run_configuration_phase(
        topo, profile, ep, DtoHyperParams(rounds=20)
    )
    p, thr = np.asarray(res.state.carry.p), res.state.thresholds
    a = simulator.simulate_slot(
        topo, profile, ep, p, thr, duration=1.0, seed=5, coalesce=False
    )
    b = simulator.simulate_slot(
        topo, profile, ep, p, thr, duration=1.0, seed=5, coalesce=True
    )
    assert a.mean_delay == b.mean_delay
    assert a.completed == b.completed and a.generated == b.generated
    np.testing.assert_array_equal(a.exit_fraction, b.exit_fraction)
    np.testing.assert_array_equal(a.mean_delay_per_stage, b.mean_delay_per_stage)


# ---------------------------------------------------------------------------
# observability riding the control plane (stream refactor equivalence)
# ---------------------------------------------------------------------------


def test_telemetry_unchanged_by_stream_cohabitation(setup, prompts):
    """Telemetry subscribed to the instrumentation stream must estimate
    exactly what it did as the engine's only observer: adding a tracer and
    metrics collector to the same stream may not perturb a single estimate
    (same events, same floats) nor the serve itself."""
    from repro.obs import MetricsCollector, SpanTracer

    span = len(prompts) / 60.0
    tele_ref = Telemetry(make_engine(setup).topo, TelemetryConfig(window_s=span))
    eng = make_engine(setup)
    ref = _serve(eng, prompts, telemetry=tele_ref)

    tele = Telemetry(make_engine(setup).topo, TelemetryConfig(window_s=span))
    eng2 = make_engine(setup)
    stats = _serve(
        eng2, prompts, telemetry=tele,
        tracer=SpanTracer(), metrics=MetricsCollector(),
    )

    # the serve is bitwise identical
    assert stats.sequences_by_rid() == ref.sequences_by_rid()
    np.testing.assert_array_equal(stats.delays, ref.delays)
    # every estimator saw the same observations
    now = span * 2
    eff_ref = tele_ref.effective_topology(eng.topo, now)
    eff = tele.effective_topology(eng2.topo, now)
    np.testing.assert_array_equal(eff.mu, eff_ref.mu)
    np.testing.assert_array_equal(eff.phi_ext, eff_ref.phi_ext)
    np.testing.assert_array_equal(eff.edge_rate, eff_ref.edge_rate)
    np.testing.assert_array_equal(
        tele.exit_fractions(now), tele_ref.exit_fractions(now)
    )
    np.testing.assert_array_equal(
        tele.queue_depths(), tele_ref.queue_depths()
    )


def test_failure_scenario_spans_stay_closed(setup, prompts):
    """Fail-stop re-execution: every re-executed request's span tree still
    tiles [arrival, retirement] exactly — the pre-failure wait shows up as
    lost time, attempts counts the re-executions, and the component sums
    still reconcile with the reported delays."""
    from repro.obs import SpanTracer, decompose

    eng = make_engine(setup)
    span = len(prompts) / 60.0
    scn = get_scenario("failure", eng.topo, p=eng.p, horizon=span)
    tracer = SpanTracer()
    # arrivals 4x faster than the scenario horizon assumes: the victim
    # replica is guaranteed to hold queued work at the failure instant, so
    # the run exercises re-execution (at the default 60/s it can drain first)
    stats = _serve(eng, prompts, scenario=scn, tracer=tracer, arrival_rate=240.0)
    assert len(stats.delays) == len(prompts)  # nobody lost

    for rid in stats.rids:
        assert tracer.check_tree(rid) == []
    dec = decompose(tracer, stats)
    assert dec["reconciles"], f"max residual {dec['max_residual_s']}"
    assert dec["num_requests"] == len(prompts)
    # at least one request rode through the failure: re-executed, with the
    # abandoned wait accounted as lost time
    resub = [rid for rid, n in tracer.attempts.items() if n > 1]
    assert stats.resubmitted > 0 and len(resub) == stats.resubmitted
    lost = {e["rid"]: e["lost"] for e in dec["per_request"]}
    assert any(lost[rid] > 0 for rid in resub)
    # failure + re-execution instants made it into the event log
    kinds = {i["kind"] for i in tracer.instants}
    assert "failure" in kinds and "resubmit" in kinds
