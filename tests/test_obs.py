"""Observability: span trees, metrics, exporters, attribution, stream.

Unit layer: the tracer/metrics/export/stream primitives driven by hand with
synthetic event sequences (exact expected spans).  Integration layer: one
traced cached-decode serve on a tiny real engine, shared across tests —
span-tree completeness under mid-decode admission, metrics totals, roofline
rows, export validation, and the disabled-path bitwise-identity guarantee.
"""
import json

import numpy as np
import pytest

import jax

from repro.configs import get_config
from repro.core.profiles import profile_from_arch
from repro.core.thresholds import synthetic_validation
from repro.core.topology import NetworkSpec, build_edge_network
from repro.core.types import DtoHyperParams
from repro.models import model as model_lib
from repro.obs import (
    SPAN_KINDS,
    Counter,
    Gauge,
    Histogram,
    MetricsCollector,
    MetricsRegistry,
    NullTracer,
    SpanTracer,
    build_stream,
    chrome_trace,
    decompose,
    roofline_utilization,
    validate_chrome_trace,
)
from repro.serving import CollaborativeEngine


# ---------------------------------------------------------------------------
# metrics primitives
# ---------------------------------------------------------------------------


def test_counter_and_gauge_basics():
    c = Counter("c")
    c.inc()
    c.inc(np.float64(2.5))  # numpy scalars must not poison the accumulator
    assert c.value == 3.5
    assert type(c.value) is float

    g = Gauge("g")
    assert np.isnan(g.value) and g.n_samples == 0
    for v in (1.0, np.float64(3.0), 2.0):
        g.set(v)
    assert g.value == 2.0 and type(g.value) is float
    assert g.max_value == 3.0
    assert g.mean == pytest.approx(2.0)
    assert g.snapshot()["n"] == 3


def test_histogram_counts_and_quantiles():
    h = Histogram("h", lo_decade=-3, hi_decade=0, per_decade=8)
    rng = np.random.default_rng(0)
    xs = rng.uniform(1e-3, 1e-1, size=2000)
    for x in xs:
        h.observe(x)
    assert h.n == xs.size
    assert sum(h.counts) == xs.size
    assert h.min == pytest.approx(xs.min())
    assert h.max == pytest.approx(xs.max())
    assert h.mean == pytest.approx(xs.mean())
    # log-bucket quantiles are exact to bucket resolution (~33% per-decade/8)
    for q in (0.5, 0.95, 0.99):
        exact = float(np.quantile(xs, q))
        assert h.quantile(q) == pytest.approx(exact, rel=0.35)
    # quantiles are monotone in q
    assert h.quantile(0.5) <= h.quantile(0.95) <= h.quantile(0.99)


def test_histogram_out_of_range_and_empty():
    h = Histogram("h", lo_decade=-2, hi_decade=0, per_decade=4)
    assert np.isnan(h.quantile(0.5))  # empty
    h.observe(0.0)  # below range (and zero): first bucket
    h.observe(1e5)  # above range: overflow bucket
    assert h.counts[0] == 1 and h.counts[-1] == 1
    assert h.n == 2
    snap = h.snapshot()
    assert snap["n"] == 2 and snap["min"] == 0.0 and snap["max"] == 1e5


def test_registry_get_or_create_and_snapshot():
    r = MetricsRegistry()
    assert r.counter("a") is r.counter("a")
    assert r.gauge("b") is r.gauge("b")
    assert r.histogram("c") is r.histogram("c")
    r.counter("a").inc(2)
    r.gauge("b").set(0.5)
    r.histogram("c").observe(1e-3)
    assert r.names() == ["a", "b", "c"]
    snap = r.snapshot()
    assert snap["a"]["value"] == 2.0
    json.dumps(snap)  # JSON-able


# ---------------------------------------------------------------------------
# span tracer driven by hand (exact expected trees)
# ---------------------------------------------------------------------------


def _emit_one_request(tr, rid=0, base=0.0):
    """Replay the engine's hook sequence for one single-hop request; the
    resulting tree tiles [base, base+0.03] exactly."""
    tr.on_submit(base, rid, ed=0, arrival=base)
    tr.on_transfer(base, base + 0.01, 0.01, src=0, dst=2, rid=rid, mb=1.0)
    tr.on_enqueue(base + 0.01, rid, node=2)
    tr.on_batch(
        base + 0.03, 2, 1.0, 0.02, 0,
        stage=1, rids=(rid,), t_dispatch=base + 0.015, t_start=base + 0.02,
        n_rows=4, n_tokens=48, is_decode=False, wall_clock_s=1e-4,
    )
    tr.on_exit(base + 0.03, rid, stage=1, conf=0.9)


def test_tracer_tiles_one_request_exactly():
    tr = SpanTracer()
    _emit_one_request(tr)
    assert tr.check_tree(0) == []
    assert tr.closed(0)
    comp = tr.components(0)
    assert comp["admission"] == 0.0
    assert comp["transfer"] == pytest.approx(0.01)
    assert comp["queue"] == pytest.approx(0.005)
    assert comp["batch_wait"] == pytest.approx(0.005)
    assert comp["compute"] == pytest.approx(0.01)
    assert sum(comp.values()) == pytest.approx(tr.done[0] - tr.arrival[0])
    assert tr.attempts[0] == 1
    assert [i["kind"] for i in tr.instants] == ["retire"]
    # the replay advanced the injected sim clock to the last event
    assert tr.clock.now == pytest.approx(0.03)


def test_tracer_resubmit_accounts_lost_time():
    tr = SpanTracer()
    tr.on_submit(0.0, 7, ed=0, arrival=0.0)
    tr.on_transfer(0.0, 0.01, 0.01, src=0, dst=2, rid=7, mb=1.0)
    tr.on_enqueue(0.01, 7, node=2)
    tr.on_failure(0.02, node=2)
    tr.on_resubmit(0.02, 7)  # engine re-submits from the ED...
    tr.on_transfer(0.02, 0.03, 0.01, src=0, dst=3, rid=7, mb=1.0)
    tr.on_enqueue(0.03, 7, node=3)
    tr.on_batch(
        0.05, 3, 1.0, 0.015, 0,
        stage=1, rids=(7,), t_dispatch=0.035, t_start=0.04,
        n_rows=1, n_tokens=12, is_decode=False, wall_clock_s=1e-4,
    )
    tr.on_exit(0.05, 7, stage=1, conf=0.8)
    assert tr.check_tree(7) == []
    assert tr.attempts[7] == 2
    lost = [s for s in tr.spans[7] if s.attrs and s.attrs.get("lost")]
    assert len(lost) == 1
    assert lost[0].duration == pytest.approx(0.01)  # the abandoned wait
    kinds = {i["kind"] for i in tr.instants}
    assert kinds == {"failure", "resubmit", "retire"}
    dec = decompose(tr)
    assert dec["reconciles"] and dec["num_with_lost_time"] == 1
    (entry,) = dec["per_request"]
    assert entry["lost"] == pytest.approx(0.01)
    assert entry["total"] == pytest.approx(0.05)


def test_check_tree_flags_violations():
    tr = SpanTracer()
    assert tr.check_tree(0) == ["rid 0: no spans"]
    tr.add_span(1, "queue", 0.0, 0.01, node=2)
    tr.add_span(1, "compute", 0.02, 0.03, node=2)  # gap: 0.01 -> 0.02
    errs = tr.check_tree(1)
    assert any("starts at" in e for e in errs)
    assert any("never closed" in e for e in errs)
    tr2 = SpanTracer()
    tr2.add_span(2, "compute", 0.05, 0.01)  # backwards
    assert any("t1 < t0" in e for e in tr2.check_tree(2))


def test_replay_cache_invalidates_on_new_events():
    tr = SpanTracer()
    _emit_one_request(tr, rid=0, base=0.0)
    assert set(tr.spans) == {0}  # materializes + caches
    _emit_one_request(tr, rid=1, base=0.1)  # event log grew after a read
    assert set(tr.spans) == {0, 1}
    assert tr.check_tree(1) == []
    assert tr.clock.now == pytest.approx(0.13)


def test_decompose_residual_against_reported_delay():
    class FakeStats:
        rids = [0]
        delays = [0.05]  # engine claims 50 ms but the tree only tiles 30

    tr = SpanTracer()
    _emit_one_request(tr)
    dec = decompose(tr, FakeStats())
    assert not dec["reconciles"]
    assert dec["max_residual_s"] == pytest.approx(0.02)


def test_null_tracer_is_inert():
    nt = NullTracer()
    nt.on_batch(0.0, 1, 1.0, 0.1, 0)  # arbitrary hooks absorb anything
    nt.on_exit(0.0, 1, 2, 0.5)
    nt.add_span(0, "queue", 0.0, 1.0)
    assert nt.wants_wall_clock is False
    with pytest.raises(AttributeError):
        nt.spans


# ---------------------------------------------------------------------------
# instrumentation stream dispatch
# ---------------------------------------------------------------------------


class _ExitCounter:
    def __init__(self):
        self.calls = []

    def on_exit(self, t, rid, stage, conf):
        self.calls.append((t, rid, stage, conf))


def test_build_stream_none_when_no_subscribers():
    assert build_stream() is None
    assert build_stream(None, None) is None


def test_stream_single_subscriber_binds_directly():
    sub = _ExitCounter()
    st = build_stream(sub, None)
    assert st.on_exit == sub.on_exit  # no fan-out indirection
    st.on_exit(1.0, 3, 2, 0.7)
    assert sub.calls == [(1.0, 3, 2, 0.7)]
    # hooks nobody defines are no-ops, not AttributeErrors
    st.on_pool(0.0, 1, 0.5)


def test_stream_fans_out_and_aggregates_wants_wall():
    a, b = _ExitCounter(), _ExitCounter()
    st = build_stream(a, b)
    st.on_exit(1.0, 3, 2, 0.7)
    assert a.calls == b.calls == [(1.0, 3, 2, 0.7)]
    assert st.wants_wall is False
    assert build_stream(a, SpanTracer()).wants_wall is True  # tracer wants it


# ---------------------------------------------------------------------------
# exporter + validator
# ---------------------------------------------------------------------------


def test_chrome_trace_of_synthetic_serve_validates():
    tr = SpanTracer()
    for rid in range(3):
        _emit_one_request(tr, rid=rid, base=0.05 * rid)
    tr.on_pool(0.2, node=2, used_fraction=0.25)
    payload = chrome_trace(tr)
    assert validate_chrome_trace(payload) == []
    json.dumps(payload)
    evs = payload["traceEvents"]
    names = {e.get("name") for e in evs if e.get("ph") == "X"}
    assert set(SPAN_KINDS) - {"batch_wait", "queue"} <= names  # admission has 0 dur but exists
    assert "stage1.prefill" in names  # the node busy track
    assert any(e["ph"] == "C" and e["name"] == "pool_occupancy" for e in evs)
    assert any(e["ph"] == "C" and e["name"] == "queue_depth" for e in evs)


def test_validate_chrome_trace_catches_corruption():
    assert validate_chrome_trace([]) != []
    assert validate_chrome_trace({}) != []
    assert "traceEvents is empty" in validate_chrome_trace({"traceEvents": []})[0]
    bad_dur = {"traceEvents": [
        {"ph": "X", "pid": 1, "tid": 0, "name": "s", "ts": 0.0, "dur": -5.0},
    ]}
    assert any("negative duration" in e for e in validate_chrome_trace(bad_dur))
    no_ts = {"traceEvents": [{"ph": "i", "pid": 1, "tid": 0, "name": "x"}]}
    assert any("ts" in e for e in validate_chrome_trace(no_ts))
    unbalanced = {"traceEvents": [
        {"ph": "E", "pid": 1, "tid": 0, "name": "s", "ts": 1.0},
    ]}
    assert any("E without matching B" in e for e in validate_chrome_trace(unbalanced))
    overlap = {"traceEvents": [
        {"ph": "X", "pid": 1, "tid": 5, "name": "a", "ts": 0.0, "dur": 10.0},
        {"ph": "X", "pid": 1, "tid": 5, "name": "b", "ts": 5.0, "dur": 10.0},
    ]}
    assert any("overlaps" in e for e in validate_chrome_trace(overlap))


# ---------------------------------------------------------------------------
# integration: one traced cached-decode serve on a tiny real engine
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def engine():
    cfg = get_config("stablelm-1.6b").reduced(
        vocab_size=128, d_model=64, d_ff=128, num_heads=2, num_kv_heads=2,
        head_dim=32,
    )
    params = model_lib.init_params(jax.random.key(0), cfg)
    profile = profile_from_arch(cfg)
    topo = build_edge_network(
        seed=0, profile=profile, spec=NetworkSpec(num_eds=4, es_per_stage=(2, 2))
    )
    ep = synthetic_validation(seed=1, profile=profile)
    eng = CollaborativeEngine(
        params, cfg, topo, profile, ep, DtoHyperParams(rounds=20), seed=0
    )
    eng.configuration_phase()
    # low thresholds: a realistic mix of early exits and full-depth requests
    eng.state.thresholds = np.full_like(eng.state.thresholds, 0.1)
    return eng


def _prompts(n, seed=2):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, 128, size=12).astype(np.int32) for _ in range(n)]


def _serve(eng, n=12, seed=7, **kw):
    eng.rng = np.random.default_rng(seed)
    # gen_len > 1 cached decode: prompts are admitted into RUNNING batches at
    # stage boundaries (continuous batching) — the hard case for the tiling
    kw.setdefault("gen_len", 3)
    kw.setdefault("decode_mode", "cached")
    return eng.serve(_prompts(n), arrival_rate=60.0, batch_size=4, **kw)


@pytest.fixture(scope="module")
def traced(engine):
    tracer, metrics = SpanTracer(), MetricsCollector()
    stats = _serve(engine, tracer=tracer, metrics=metrics)
    return stats, tracer, metrics


def test_serve_span_trees_tile_mid_decode_admission(traced):
    stats, tracer, _ = traced
    assert len(stats.delays) == 12
    for rid in stats.rids:
        assert tracer.check_tree(rid) == []
    dec = decompose(tracer, stats)
    assert dec["reconciles"], f"max residual {dec['max_residual_s']}"
    assert dec["num_requests"] == 12
    # components actually exercised: every kind shows up somewhere
    seen = {s.kind for spans in tracer.spans.values() for s in spans}
    assert seen == set(SPAN_KINDS)
    # decode compute spans exist (gen_len=3) and are flagged as such
    assert any(
        s.kind == "compute" and s.attrs and s.attrs.get("decode")
        for spans in tracer.spans.values() for s in spans
    )


def test_serve_metrics_totals_match_stats(traced):
    stats, _, metrics = traced
    r = metrics.registry
    s = stats.summary()
    assert r.counter("requests_submitted").value == 12
    assert r.histogram("delay_s").n == 12
    assert r.counter("batches").value == s["num_batches"]
    assert r.counter("forward_rows").value == s["num_forward_rows"]
    assert r.counter("real_rows").value == s["num_real_rows"]
    assert metrics.padded_row_frac() == pytest.approx(s["padded_row_frac"])
    assert r.histogram("delay_s").mean == pytest.approx(s["mean_delay"], rel=1e-6)
    exit_hist = metrics.realized_exit_histogram()
    assert sum(exit_hist.values()) == 12
    assert exit_hist == {
        stage: count
        for stage, count in zip(*np.unique(
            [v[0] for v in stats.by_rid().values()], return_counts=True
        ))
    }
    json.dumps(metrics.snapshot())


def test_serve_trace_exports_and_validates(traced):
    _, tracer, _ = traced
    payload = chrome_trace(tracer)
    assert validate_chrome_trace(payload) == []
    # both request tracks and node busy tracks are present
    pids = {e["pid"] for e in payload["traceEvents"]}
    assert 1 in pids and any(p >= 1000 for p in pids)


def test_serve_roofline_rows(traced, engine):
    _, tracer, _ = traced
    rows = roofline_utilization(tracer, engine.cfg)
    assert rows
    phases = {r["phase"] for r in rows.values()}
    assert phases == {"prefill", "decode"}
    for row in rows.values():
        assert row["calls"] > 0 and row["device_tokens"] > 0
        assert row["measured_wall_s"] > 0  # wants_wall_clock was honored
        assert row["bound_s"] > 0
        assert np.isfinite(row["utilization"]) and row["utilization"] > 0


def test_disabled_path_is_bitwise_identical(engine, traced):
    stats_traced, _, _ = traced
    stats_off = _serve(engine)  # same seed/workload, no observers
    assert stats_off.by_rid() == stats_traced.by_rid()
    assert all(
        a == b for a, b in zip(stats_off.delays, stats_traced.delays)
    )
