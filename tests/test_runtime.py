"""Checkpointing (atomicity, validation) + failure/elastic handling."""
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import dto_ee
from repro.core.thresholds import synthetic_validation
from repro.core.topology import build_edge_network
from repro.core.types import DtoHyperParams, RESNET101_PROFILE
from repro.runtime import (
    CheckpointManager,
    elastic_remesh,
    handle_failure,
    renormalize_strategy,
)

PROFILE = RESNET101_PROFILE


def _tree(seed=0):
    k = jax.random.key(seed)
    return {
        "layer": {"w": jax.random.normal(k, (8, 16)), "b": jnp.zeros((16,))},
        "stack": [jnp.ones((3, 3)), jnp.arange(5, dtype=jnp.int32)],
    }


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = _tree()
    mgr.save(7, tree, extra={"note": "hi"})
    restored, manifest = mgr.restore(jax.eval_shape(lambda: tree))
    assert manifest["step"] == 7 and manifest["extra"]["note"] == "hi"
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_latest_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree())
    assert mgr.latest_step() == 4
    assert mgr.all_steps() == [3, 4]  # gc kept 2


def test_checkpoint_rejects_shape_mismatch(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"w": jnp.zeros((4, 4))})
    with pytest.raises(ValueError):
        mgr.restore({"w": jax.ShapeDtypeStruct((5, 4), jnp.float32)})


def test_checkpoint_rejects_missing_leaf(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"w": jnp.zeros((4,))})
    with pytest.raises((KeyError, ValueError)):
        mgr.restore({"q": jax.ShapeDtypeStruct((4,), jnp.float32)})


def test_checkpoint_torn_write_invisible(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree())
    # simulate a torn write: tmp dir left behind by a crashed process
    os.makedirs(tmp_path / "step_00000009.tmp-999", exist_ok=True)
    assert mgr.latest_step() == 1
    restored, manifest = mgr.restore(jax.eval_shape(_tree))
    assert manifest["step"] == 1


# ---------------------------------------------------------------------------
# failure / elastic
# ---------------------------------------------------------------------------


def _net(seed=0):
    topo = build_edge_network(seed=seed, profile=PROFILE, arrival_rate_scale=2.0)
    ep = synthetic_validation(seed=1, profile=PROFILE)
    res = dto_ee.solve(topo, PROFILE, ep, DtoHyperParams(), adapt_thresholds=False)
    return topo, ep, np.asarray(res.state.carry.p)


def test_failure_renormalizes_to_simplex():
    topo, ep, p = _net()
    victim = int(topo.nodes_at_stage(2)[0])
    topo2, p2 = handle_failure(topo, p, victim)
    assert victim not in set(topo2.edge_dst.tolist())
    sums = np.zeros(topo2.num_nodes)
    np.add.at(sums, topo2.edge_src, p2)
    senders = np.unique(topo2.edge_src)
    np.testing.assert_allclose(sums[senders], 1.0, atol=1e-9)


def test_failure_then_rebalance_restores_stability():
    import jax.numpy as jnp

    from repro.core import queueing

    topo, ep, p = _net()
    victim = int(topo.nodes_at_stage(2)[0])
    topo2, p2 = handle_failure(topo, p, victim)
    res = dto_ee.solve(topo2, PROFILE, ep, DtoHyperParams(), adapt_thresholds=False)
    I_node = jnp.ones(topo2.num_nodes)
    _, lam = queueing.steady_state_flows(res.state.carry.p, topo2, PROFILE, I_node)
    assert bool(queueing.is_stable(topo2, lam))


def test_elastic_remesh_adds_replicas_and_keeps_mass():
    topo, ep, p = _net()
    n_before = len(topo.nodes_at_stage(2))
    topo3, p3 = elastic_remesh(topo, p, stage=2, add_replicas=2)
    assert len(topo3.nodes_at_stage(2)) == n_before + 2
    topo3.validate()
    sums = np.zeros(topo3.num_nodes)
    np.add.at(sums, topo3.edge_src, p3)
    senders = np.unique(topo3.edge_src)
    np.testing.assert_allclose(sums[senders], 1.0, atol=1e-9)


def test_renormalize_uniform_fallback():
    topo, _, p = _net()
    z = np.zeros_like(p)  # degenerate: every source lost its mass
    p2 = renormalize_strategy(topo, z)
    sums = np.zeros(topo.num_nodes)
    np.add.at(sums, topo.edge_src, p2)
    senders = np.unique(topo.edge_src)
    np.testing.assert_allclose(sums[senders], 1.0, atol=1e-9)
