"""Micro-batched data plane: batched-vs-sequential equivalence, shape
bucketing, Poisson arrivals, and the fused final head."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.profiles import profile_from_arch
from repro.core.thresholds import synthetic_validation
from repro.core.topology import NetworkSpec, build_edge_network
from repro.core.types import DtoHyperParams
from repro.models import layers, model as model_lib
from repro.serving import CollaborativeEngine, Request, ShapeBucketBatcher
from repro.serving.batching import batch_tokens, padded_batch_size


@pytest.fixture(scope="module")
def engine():
    cfg = get_config("stablelm-1.6b").reduced(vocab_size=128)
    params = model_lib.init_params(jax.random.key(0), cfg)
    profile = profile_from_arch(cfg)
    topo = build_edge_network(
        seed=0, profile=profile, spec=NetworkSpec(num_eds=4, es_per_stage=(2, 2))
    )
    ep = synthetic_validation(seed=1, profile=profile)
    eng = CollaborativeEngine(
        params, cfg, topo, profile, ep, DtoHyperParams(rounds=20), seed=0
    )
    eng.configuration_phase()
    return eng


def _prompts(n, vocab=128, length=12, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, vocab, size=length).astype(np.int32) for _ in range(n)]


# ---------------------------------------------------------------------------
# batched vs sequential engine equivalence
# ---------------------------------------------------------------------------


def _serve(engine, prompts, batch_size, seed=7):
    engine.rng = np.random.default_rng(seed)
    return engine.serve(prompts, arrival_rate=1e5, batch_size=batch_size)


def test_batched_serve_matches_sequential_exits(engine):
    prompts = _prompts(16)
    seq = _serve(engine, prompts, batch_size=1)
    for bs in (4, 8):
        bat = _serve(engine, prompts, batch_size=bs)
        assert bat.by_rid() == seq.by_rid()  # same exits, same tokens per rid
        assert len(bat.delays) == len(prompts)
        assert bat.num_batches < seq.num_batches
        assert all(np.isfinite(bat.delays))


def test_batched_serve_confidences_match(engine):
    prompts = _prompts(12, seed=3)
    seq = _serve(engine, prompts, batch_size=1)
    bat = _serve(engine, prompts, batch_size=8)
    c_seq = {r: c for r, c in zip(seq.rids, seq.confidences)}
    c_bat = {r: c for r, c in zip(bat.rids, bat.confidences)}
    for rid in c_seq:
        assert c_bat[rid] == pytest.approx(c_seq[rid], abs=1e-5)


def test_mixed_prompt_lengths_bucket_by_shape(engine):
    rng = np.random.default_rng(5)
    prompts = [
        rng.integers(0, 128, size=length).astype(np.int32)
        for length in (8, 12, 8, 12, 8, 12, 8, 12)
    ]
    seq = _serve(engine, prompts, batch_size=1)
    bat = _serve(engine, prompts, batch_size=4)
    assert bat.by_rid() == seq.by_rid()
    assert len(bat.delays) == len(prompts)


def test_poisson_arrivals_complete_and_scale_with_rate(engine):
    prompts = _prompts(10)
    engine.rng = np.random.default_rng(11)
    fast = engine.serve(prompts, arrival_rate=1e5, batch_size=2)
    engine.rng = np.random.default_rng(11)
    slow = engine.serve(prompts, arrival_rate=1.0, batch_size=2)
    assert len(fast.delays) == len(slow.delays) == len(prompts)
    # at rate 1e5 every request is queued behind its predecessors; at rate 1
    # the system drains between arrivals, so queueing delay must shrink
    assert np.mean(slow.delays) < np.mean(fast.delays)


# ---------------------------------------------------------------------------
# fused final head == reference softmax head
# ---------------------------------------------------------------------------


def test_fused_final_head_matches_softmax_reference(engine):
    cfg = engine.cfg
    params = engine.programs.params
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((5, 1, cfg.d_model)), cfg.dtype)
    conf, tok = model_lib.final_confidence(params, x, cfg)
    h = layers.apply_norm(cfg.norm, params["final_norm"], x)
    logits = model_lib.lm_logits(params, h, cfg)[:, 0]
    ref_conf = jax.nn.softmax(logits, axis=-1).max(axis=-1)
    ref_tok = jnp.argmax(logits, axis=-1)
    # fused path runs the head matmul in the activation dtype (bf16 for this
    # config) with f32 accumulation; the reference keeps f32 logits
    np.testing.assert_allclose(np.asarray(conf), np.asarray(ref_conf), atol=2e-3)
    assert bool(jnp.all(tok == ref_tok))


# ---------------------------------------------------------------------------
# batching utilities
# ---------------------------------------------------------------------------


def test_shape_bucket_batcher_fifo_across_buckets():
    b = ShapeBucketBatcher(batch_size=2)
    order = [("a", 0), ("b", 1), ("a", 2), ("a", 3), ("b", 4)]
    for key, rid in order:
        b.push(key, Request(rid=rid, tokens=np.arange(3), arrival=float(rid)))
    assert len(b) == 5
    key, batch = b.pop_batch()  # oldest head is rid 0 in bucket "a"
    assert key == "a" and [r.rid for r in batch] == [0, 2]
    key, batch = b.pop_batch()  # now bucket "b"'s head (rid 1) is oldest
    assert key == "b" and [r.rid for r in batch] == [1, 4]
    key, batch = b.pop_batch()
    assert key == "a" and [r.rid for r in batch] == [3]
    assert b.pop_batch() is None and len(b) == 0


def test_padded_batch_size_powers_of_two():
    assert [padded_batch_size(n, 32) for n in (1, 2, 3, 5, 9, 31, 32, 40)] == [
        1, 2, 4, 8, 16, 32, 32, 32,
    ]


def test_batch_tokens_pads_batch_dim():
    reqs = [
        Request(rid=i, tokens=np.arange(4, dtype=np.int32), arrival=0.0)
        for i in range(3)
    ]
    out = batch_tokens(reqs, batch_size=8)
    assert out.shape == (4, 4)  # 3 rows -> next pow2
    assert (out[3] == 0).all()
