"""Cache-threaded autoregressive decode plane: staged engine vs monolithic
``model.prefill`` + ``model.decode_step``, continuous batching, slot rings,
and the ragged one-token stage programs."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.profiles import profile_from_arch
from repro.core.thresholds import synthetic_validation
from repro.core.topology import NetworkSpec, build_edge_network
from repro.core.types import DtoHyperParams
from repro.models import model as model_lib
from repro.serving import (
    CollaborativeEngine,
    Request,
    ShapeBucketBatcher,
    SlotRing,
    monolithic_generate,
)

GEN = 6
# mid-range threshold: the fixed workload below then mixes requests exiting
# early on token 1, mid-generation, and running to gen_len (verified mix:
# exit stages {2, 3, 4}, sequence lengths 1..GEN)
THRESHOLD = 0.1


@pytest.fixture(scope="module")
def engine():
    cfg = get_config("stablelm-1.6b").reduced(vocab_size=128)
    params = model_lib.init_params(jax.random.key(0), cfg)
    profile = profile_from_arch(cfg)
    topo = build_edge_network(
        seed=0, profile=profile, spec=NetworkSpec(num_eds=4, es_per_stage=(2, 2))
    )
    ep = synthetic_validation(seed=1, profile=profile)
    eng = CollaborativeEngine(
        params, cfg, topo, profile, ep, DtoHyperParams(rounds=20), seed=0
    )
    eng.configuration_phase()
    eng.state.thresholds = np.full_like(eng.state.thresholds, THRESHOLD)
    return eng


@pytest.fixture(scope="module")
def prompts():
    rng = np.random.default_rng(2)
    return [
        rng.integers(0, 128, size=length).astype(np.int32)
        for length in (12, 8, 12, 8, 12, 8, 12, 8)
    ]


@pytest.fixture(scope="module")
def reference(engine, prompts):
    """Monolithic single-host ground truth, per request."""
    return {
        i: (stage, tuple(toks))
        for i, p in enumerate(prompts)
        for toks, stage in [
            monolithic_generate(
                engine.programs.params, engine.cfg, p, engine.thresholds, GEN
            )
        ]
    }


def _serve(engine, prompts, seed=7, **kw):
    engine.rng = np.random.default_rng(seed)
    return engine.serve(prompts, arrival_rate=1e5, batch_size=4, gen_len=GEN, **kw)


# ---------------------------------------------------------------------------
# token-identical equivalence: staged+cached == staged+stateless == monolithic
# ---------------------------------------------------------------------------


def test_reference_mixes_early_and_late_exits(reference):
    lens = sorted(len(toks) for _, toks in reference.values())
    assert lens[0] == 1 and lens[-1] == GEN
    assert any(1 < n < GEN for n in lens)  # mid-generation early exit


def test_cached_decode_matches_monolithic(engine, prompts, reference):
    stats = _serve(engine, prompts, decode_mode="cached")
    assert stats.sequences_by_rid() == reference
    assert len(stats.delays) == len(prompts)
    assert all(np.isfinite(stats.delays))


def test_stateless_decode_matches_monolithic(engine, prompts, reference):
    stats = _serve(engine, prompts, decode_mode="stateless")
    assert stats.sequences_by_rid() == reference


def test_continuous_batching_admission_mid_decode(engine, prompts, reference):
    """Slow arrivals: later prompts are admitted into replicas whose slot
    rings already hold mid-decode residents; outputs must not change."""
    engine.rng = np.random.default_rng(11)
    stats = engine.serve(
        prompts, arrival_rate=50.0, batch_size=4, gen_len=GEN, num_slots=3
    )
    assert stats.sequences_by_rid() == reference


def test_early_exit_retires_slots_under_pressure(engine, prompts, reference):
    """A 2-slot ring forces admission to wait on retirements; early-exited
    rows must free their slots at every stage they visited."""
    stats = _serve(engine, prompts, num_slots=2)
    assert stats.sequences_by_rid() == reference
    assert len(stats.delays) == len(prompts)


def test_cached_decode_batch_size_invariant(engine, prompts):
    a = _serve(engine, prompts, seed=9, decode_mode="cached")
    engine.rng = np.random.default_rng(9)
    b = engine.serve(prompts, arrival_rate=1e5, batch_size=1, gen_len=GEN)
    assert a.sequences_by_rid() == b.sequences_by_rid()


def test_classification_default_unchanged(engine, prompts, reference):
    """gen_len=1 keeps the paper's single-shot semantics: one token, exit at
    the first confident branch; the token equals the reference's first."""
    engine.rng = np.random.default_rng(7)
    stats = engine.serve(prompts, arrival_rate=1e5, batch_size=4)
    assert len(stats.delays) == len(prompts)
    for rid, (_, toks) in reference.items():
        assert stats.sequences_by_rid()[rid][1] == toks[:1]


# ---------------------------------------------------------------------------
# ragged per-stage programs == monolithic stage math
# ---------------------------------------------------------------------------


def test_ragged_stage_decode_matches_monolithic(engine):
    """Per-row-position cached decode (slot layout) reproduces the scalar-
    position monolithic decode exactly when rows share a position."""
    cfg = engine.cfg
    params = engine.programs.params
    rng = np.random.default_rng(3)
    B, S, max_len = 3, 10, 16
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    _, _, _, caches = model_lib.prefill(params, {"tokens": toks}, cfg, max_len)
    step = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 1)), jnp.int32)
    x = model_lib._embed_inputs(params, {"tokens": step}, cfg)

    x_mono = x
    mono_caches = caches
    x_rag = x
    for stage_idx in range(1, cfg.num_stages + 1):
        x_mono, mono_nc = model_lib._decode_stage(
            params["stages"][stage_idx - 1], x_mono, mono_caches[stage_idx - 1], cfg
        )
        # ragged layout: same rows, pos as a per-row vector
        rag_cache = jax.tree.map(lambda a: a, caches[stage_idx - 1])

        def vec_pos(c):
            return {
                k: (jnp.broadcast_to(v, (v.shape[0], B)) if k == "pos" else v)
                for k, v in c.items()
            }

        rag_cache = tuple(vec_pos(c) for c in rag_cache)
        x_rag, _ = model_lib.decode_stage_ragged(params, stage_idx, x_rag, rag_cache, cfg)
        np.testing.assert_array_equal(np.asarray(x_mono), np.asarray(x_rag))
        mono_caches = list(mono_caches)
        mono_caches[stage_idx - 1] = mono_nc


def test_slot_store_rows_independent(engine):
    """Writing one request's prefill rows into a slot store and decoding it
    must be unaffected by unrelated residents (row isolation)."""
    from repro.serving import steps

    cfg = engine.cfg
    params = engine.programs.params
    rng = np.random.default_rng(4)
    S, max_len, n_slots = 8, 14, 4
    store = model_lib.init_stage_slot_caches(cfg, 1, n_slots + 1, max_len)
    write = steps.make_slot_write(cfg, 1)
    decode = steps.make_stage_decode(cfg, 1)
    prefill = steps.make_stage_prefill(cfg, 1, max_len)

    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, S)), jnp.int32)
    x = model_lib._embed_inputs(params, {"tokens": toks}, cfg)
    x_out, caches = prefill(params, x)
    store = write(store, caches, jnp.asarray([2, 0], jnp.int32))

    step = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 1)), jnp.int32)
    xs = model_lib._embed_inputs(params, {"tokens": step}, cfg)
    # decode the two residents in opposite slot order; then one at a time
    y_both, store2 = decode(params, xs, store, jnp.asarray([2, 0], jnp.int32))
    del store2
    store_b = model_lib.init_stage_slot_caches(cfg, 1, n_slots + 1, max_len)
    store_b = write(store_b, caches, jnp.asarray([2, 0], jnp.int32))
    y_one, _ = decode(params, xs[:1], store_b, jnp.asarray([2], jnp.int32))
    np.testing.assert_array_equal(np.asarray(y_both)[:1], np.asarray(y_one))


# ---------------------------------------------------------------------------
# padded-row accounting + slot ring
# ---------------------------------------------------------------------------


def test_summary_reports_padded_waste_and_tokens(engine, prompts):
    stats = _serve(engine, prompts, decode_mode="cached")
    s = stats.summary()
    assert s["num_real_rows"] <= s["num_forward_rows"]
    assert 0.0 <= s["padded_row_frac"] < 1.0
    assert s["generated_tokens"] == sum(len(g) for g in stats.gen_tokens)
    assert np.isfinite(s["sim_tokens_per_s"]) and s["sim_tokens_per_s"] > 0


def test_slot_ring_alloc_free_cycle():
    ring = SlotRing(2)
    a, b = ring.alloc(), ring.alloc()
    assert {a, b} == {0, 1}
    assert ring.alloc() is None and ring.available == 0
    ring.free(a)
    assert ring.available == 1 and ring.alloc() == a
    with pytest.raises(ValueError):
        ring.free(5)


def test_slot_ring_rejects_double_free():
    ring = SlotRing(3)
    s = ring.alloc()
    ring.free(s)
    with pytest.raises(ValueError):
        ring.free(s)


def test_shape_bucket_batcher_partial_take():
    b = ShapeBucketBatcher(batch_size=4)
    for rid in range(5):
        b.push("a", Request(rid=rid, tokens=np.arange(3), arrival=float(rid)))
    assert b.head_seq() == 0
    key, batch = b.pop_batch(max_take=2)
    assert [r.rid for r in batch] == [0, 1]
    key, batch = b.pop_batch()
    assert [r.rid for r in batch] == [2, 3, 4]
    assert b.head_seq() is None and b.pop_batch() is None


def test_arrival_nodes_follow_phi_ext(engine, prompts):
    """End-device assignment samples proportional to phi_ext, not round-robin:
    zeroing all-but-one ED's external rate must route every request there."""
    topo = engine.topo
    eds = topo.nodes_at_stage(0)
    keep = int(eds[1])
    saved = topo.phi_ext.copy()
    try:
        topo.phi_ext[eds] = 0.0
        topo.phi_ext[keep] = 5.0
        engine.rng = np.random.default_rng(3)
        n = len(prompts)
        ed_w = topo.phi_ext[eds]
        idx = engine.rng.choice(len(eds), size=n, p=ed_w / ed_w.sum())
        assert all(int(eds[i]) == keep for i in idx)
        # the engine draws from the same distribution: serve() must complete
        engine.rng = np.random.default_rng(3)
        stats = engine.serve(prompts, arrival_rate=1e5, batch_size=4)
        assert len(stats.delays) == n
    finally:
        topo.phi_ext[:] = saved
