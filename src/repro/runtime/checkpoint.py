"""Fault-tolerant checkpointing: sharded npz + manifest, atomic rename.

Layout (one directory per step):

    <root>/step_000123/
        manifest.json        # step, arch, leaf index, dtypes/shapes
        shard_00000.npz      # this host's leaves (per-process on multi-host)
    <root>/LATEST            # atomic pointer file

Writes go to ``step_x.tmp-<pid>`` then ``os.replace`` — a torn write can
never be seen as a valid checkpoint, and LATEST flips only after fsync.
Restore picks LATEST (or an explicit step), validates the manifest against
the live pytree structure, and rebuilds arrays with the caller's shardings.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import time
from typing import Any

import numpy as np

import jax


def _leaf_paths(tree: Any) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        parts = []
        for k in path:
            parts.append(str(getattr(k, "key", getattr(k, "idx", k))))
        out.append(("/".join(parts), leaf))
    return out


@dataclasses.dataclass
class CheckpointManager:
    root: str
    keep: int = 3

    def __post_init__(self) -> None:
        os.makedirs(self.root, exist_ok=True)

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree: Any, extra: dict | None = None) -> str:
        final = os.path.join(self.root, f"step_{step:08d}")
        tmp = final + f".tmp-{os.getpid()}"
        os.makedirs(tmp, exist_ok=True)

        leaves = _leaf_paths(tree)
        arrays = {}
        index = []
        for i, (name, leaf) in enumerate(leaves):
            arr = np.asarray(jax.device_get(leaf))
            key = f"leaf_{i:05d}"
            arrays[key] = arr
            index.append(
                {"name": name, "key": key, "dtype": str(arr.dtype), "shape": list(arr.shape)}
            )
        np.savez(os.path.join(tmp, "shard_00000.npz"), **arrays)
        manifest = {
            "step": step,
            "time": time.time(),
            "num_leaves": len(index),
            "index": index,
            "extra": extra or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)

        latest_tmp = os.path.join(self.root, f".LATEST.tmp-{os.getpid()}")
        with open(latest_tmp, "w") as f:
            f.write(os.path.basename(final))
            f.flush()
            os.fsync(f.fileno())
        os.replace(latest_tmp, os.path.join(self.root, "LATEST"))
        self._gc()
        return final

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.root, f"step_{s:08d}"), ignore_errors=True)

    # -- restore --------------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.root):
            if name.startswith("step_") and ".tmp" not in name:
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    continue
        return sorted(out)

    def latest_step(self) -> int | None:
        pointer = os.path.join(self.root, "LATEST")
        if os.path.exists(pointer):
            with open(pointer) as f:
                name = f.read().strip()
            path = os.path.join(self.root, name, "manifest.json")
            if os.path.exists(path):
                return int(name.split("_")[1])
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(
        self, tree_like: Any, step: int | None = None, shardings: Any = None
    ) -> tuple[Any, dict]:
        """Rebuild a pytree shaped like ``tree_like``; returns (tree, manifest).

        ``tree_like`` may hold arrays or ShapeDtypeStructs; names and shapes
        are validated leaf-by-leaf, so restoring into a mismatched model
        config fails loudly instead of silently transposing weights.
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {self.root}")
        d = os.path.join(self.root, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(d, "shard_00000.npz"))

        want = _leaf_paths(tree_like)
        if len(want) != manifest["num_leaves"]:
            raise ValueError(
                f"checkpoint has {manifest['num_leaves']} leaves, "
                f"model expects {len(want)}"
            )
        by_name = {e["name"]: e for e in manifest["index"]}
        flat_shardings = (
            [s for _, s in _leaf_paths(shardings)] if shardings is not None else None
        )
        leaves = []
        for i, (name, leaf) in enumerate(want):
            entry = by_name.get(name)
            if entry is None:
                raise KeyError(f"checkpoint missing leaf {name!r}")
            arr = data[entry["key"]]
            if tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(
                    f"{name}: checkpoint shape {arr.shape} != model {leaf.shape}"
                )
            if flat_shardings is not None:
                leaves.append(jax.device_put(arr, flat_shardings[i]))
            else:
                leaves.append(jax.numpy.asarray(arr))
        treedef = jax.tree_util.tree_structure(tree_like)
        return jax.tree_util.tree_unflatten(treedef, leaves), manifest
