"""int8 gradient compression with error feedback.

Motivation (the paper's beta/r cost term applied to training): cross-pod
data-parallel gradient reduction crosses DCN, the slowest hop in the mesh —
exactly the link the paper's transmission-delay term prices.  Quantizing
the cross-pod reduction to int8 cuts that traffic 4x (vs f32 master grads);
error feedback keeps the bias from accumulating (the compression residual
is replayed into the next step's gradient).

The codec is layout-preserving (per-tensor symmetric scale), so it composes
with any sharding: quantize -> psum over the slow axis -> dequantize.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def quantize_int8(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor int8: returns (q int8, scale f32)."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_tree(grads: Any, error: Any) -> tuple[Any, Any, Any]:
    """Quantize (grads + error); returns (q_tree, scale_tree, new_error).

    new_error is the residual (input - dequantized), fed back next step.
    """
    def one(g, e):
        x = g.astype(jnp.float32) + e
        q, s = quantize_int8(x)
        return q, s, x - dequantize_int8(q, s)

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(error)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    q_tree = treedef.unflatten([o[0] for o in out])
    s_tree = treedef.unflatten([o[1] for o in out])
    e_tree = treedef.unflatten([o[2] for o in out])
    return q_tree, s_tree, e_tree


def decompress_tree(q_tree: Any, s_tree: Any) -> Any:
    return jax.tree.map(dequantize_int8, q_tree, s_tree)


def init_error(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
