"""Failure handling + elastic re-meshing for the collaborative deployment.

The paper's control plane already IS the graceful-degradation mechanism:
an overloaded or dead replica's repulsive factor Delta explodes (queueing
term + exterior penalty), so traffic drains away within a few RUR/RUS
rounds with no global coordination.  This module supplies the harder edges:

  * ``handle_failure``      — drop a dead replica from the topology and
    renormalize the offloading strategy (warm start: surviving mass is
    rescaled, not reset — the paper's Eq. 19 dynamics then re-balance).
  * ``elastic_remesh``      — rebuild the topology when replicas join/leave
    a stage, carrying over offloading probabilities for surviving edges.
  * ``StragglerMonitor``    — EWMA service-rate tracker per replica; a
    throttled replica's mu estimate sinks, which feeds straight back into
    the DTO-R RUS messages (the paper's dynamic-environment adaptation).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import topology as topo_lib
from repro.core.types import Topology


def renormalize_strategy(topo: Topology, p: np.ndarray) -> np.ndarray:
    """Per-source renormalization after edges were dropped/added (uniform
    where a source lost all probability mass)."""
    p = np.maximum(np.asarray(p, np.float64), 0.0)
    sums = np.zeros(topo.num_nodes)
    np.add.at(sums, topo.edge_src, p)
    deg = np.maximum(np.diff(topo.edge_offsets), 1)
    uniform = 1.0 / deg[topo.edge_src]
    ok = sums[topo.edge_src] > 1e-12
    return np.where(ok, p / np.maximum(sums[topo.edge_src], 1e-12), uniform)


def handle_failure(
    topo: Topology, p: np.ndarray, dead_node: int
) -> tuple[Topology, np.ndarray]:
    """Remove ``dead_node``; surviving edges keep their relative mass.

    Raises RuntimeError (from ``with_node_failure``) if the failure strands
    an offloader — the caller escalates to ``elastic_remesh``.
    """
    old_edges = list(zip(topo.edge_src.tolist(), topo.edge_dst.tolist()))
    new_topo = topo_lib.with_node_failure(topo, dead_node)
    keep = {
        (s, d): i for i, (s, d) in enumerate(old_edges) if s != dead_node and d != dead_node
    }
    p_new = np.zeros(new_topo.num_edges)
    for i, (s, d) in enumerate(
        zip(new_topo.edge_src.tolist(), new_topo.edge_dst.tolist())
    ):
        p_new[i] = p[keep[(s, d)]]
    return new_topo, renormalize_strategy(new_topo, p_new)


def elastic_remesh(
    topo: Topology,
    p: np.ndarray,
    stage: int,
    add_replicas: int = 0,
    mu_new: float = 100.0,
    rng: np.random.Generator | None = None,
) -> tuple[Topology, np.ndarray]:
    """Grow stage ``stage`` by ``add_replicas`` nodes (scale-out), wiring
    each new replica to every stage-(h-1) node and every stage-(h+1) node
    it can reach.  Surviving edges keep their probability mass; new edges
    start at a small epsilon so Eq. 19 can ramp them based on measured Delta.
    """
    rng = rng or np.random.default_rng(0)
    H = topo.num_stages
    assert 1 <= stage <= H
    n_old = topo.num_nodes
    new_ids = np.arange(n_old, n_old + add_replicas, dtype=np.int32)

    node_stage = np.concatenate([topo.node_stage, np.full(add_replicas, stage, np.int32)])
    mu = np.concatenate([topo.mu, np.full(add_replicas, mu_new)])
    phi_ext = np.concatenate([topo.phi_ext, np.zeros(add_replicas)])

    old_pairs = list(zip(topo.edge_src.tolist(), topo.edge_dst.tolist()))
    pairs = list(old_pairs)
    rates = topo.edge_rate.tolist()
    preds = np.nonzero(topo.node_stage == stage - 1)[0]
    succs = np.nonzero(topo.node_stage == stage + 1)[0] if stage < H else []
    for nid in new_ids:
        for s in preds:
            pairs.append((int(s), int(nid)))
            rates.append(float(rng.uniform(10.0, 20.0)))
        for d in succs:
            pairs.append((int(nid), int(d)))
            rates.append(float(rng.uniform(10.0, 20.0)))

    order = np.lexsort((np.array([d for _, d in pairs]), np.array([s for s, _ in pairs])))
    pairs_sorted = [pairs[i] for i in order]
    rates_sorted = np.array(rates)[order]
    edge_src = np.array([s for s, _ in pairs_sorted], np.int32)
    edge_dst = np.array([d for _, d in pairs_sorted], np.int32)
    counts = np.bincount(edge_src, minlength=n_old + add_replicas)
    edge_offsets = np.concatenate([[0], np.cumsum(counts)]).astype(np.int32)

    new_topo = Topology(
        node_stage=node_stage,
        mu=mu,
        phi_ext=phi_ext,
        edge_src=edge_src,
        edge_dst=edge_dst,
        edge_rate=rates_sorted,
        edge_offsets=edge_offsets,
    )
    new_topo.validate()

    old_lookup = {pair: i for i, pair in enumerate(old_pairs)}
    eps = 0.02
    p_new = np.empty(len(pairs_sorted))
    for i, pair in enumerate(pairs_sorted):
        j = old_lookup.get(pair)
        p_new[i] = p[j] if j is not None else eps
    return new_topo, renormalize_strategy(new_topo, p_new)


@dataclasses.dataclass
class StragglerMonitor:
    """EWMA service-rate estimates driving the mu each DTO-R advertises."""

    mu_hat: np.ndarray  # [N] GFLOP/s estimates
    alpha: float = 0.3

    @classmethod
    def from_topology(cls, topo: Topology, alpha: float = 0.3) -> "StragglerMonitor":
        return cls(mu_hat=np.where(np.isinf(topo.mu), 1e30, topo.mu).copy(), alpha=alpha)

    def observe(self, node: int, gflops_done: float, wall_seconds: float) -> None:
        if wall_seconds <= 0:
            return
        rate = gflops_done / wall_seconds
        self.mu_hat[node] = (1 - self.alpha) * self.mu_hat[node] + self.alpha * rate

    def throttled(self, topo: Topology, factor: float = 0.5) -> np.ndarray:
        """Nodes whose estimated rate fell below ``factor`` of nameplate."""
        nominal = np.where(np.isinf(topo.mu), 1e30, topo.mu)
        return np.nonzero(self.mu_hat < factor * nominal)[0]

    def as_topology(self, topo: Topology) -> Topology:
        """Topology with mu replaced by the current estimates (what the
        control plane should optimize against)."""
        import dataclasses as dc

        mu = np.where(np.isinf(topo.mu), np.inf, self.mu_hat)
        return dc.replace(topo, mu=mu)
