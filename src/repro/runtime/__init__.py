from repro.runtime.checkpoint import CheckpointManager
from repro.runtime.compression import (
    compress_tree,
    decompress_tree,
    dequantize_int8,
    init_error,
    quantize_int8,
)
from repro.runtime.elastic import (
    StragglerMonitor,
    elastic_remesh,
    handle_failure,
    renormalize_strategy,
)

__all__ = [
    "CheckpointManager",
    "compress_tree", "decompress_tree", "dequantize_int8", "init_error", "quantize_int8",
    "StragglerMonitor", "elastic_remesh", "handle_failure", "renormalize_strategy",
]
