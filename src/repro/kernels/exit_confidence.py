"""Pallas TPU fused early-exit confidence head — the paper-specific hot spot.

The exit branch b_h needs only two scalars per row to apply the paper's
threshold test (conf >= c_h): the top-1 softmax probability and its argmax.
The naive path materializes [batch, vocab] logits in HBM (for qwen2.5-32b:
128 x 152064 x 4B = 78 MB written + read back per exit stage per decode
step).  This kernel streams vocab tiles of the LM head through VMEM,
matmuls on the MXU, and keeps a running (max, sum-exp, argmax) — the
logits never leave VMEM.

  grid = (batch_blocks, vocab_blocks); vocab axis sequential, carrying
  (m, l, argmax) scratch.  conf = 1 / sum_v exp(logit_v - max) because the
  top-1 term contributes exp(0).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG_INF = -1e30


def _exit_kernel(
    h_ref,  # [block_b, d]
    w_ref,  # [d, block_v]
    conf_ref,  # [block_b]
    idx_ref,  # [block_b]
    m_scr,  # [block_b, 128] f32 running max
    l_scr,  # [block_b, 128] f32 running sum-exp
    a_scr,  # [block_b, 128] i32 running argmax
    *,
    block_v: int,
    vocab: int,
    num_v_blocks: int,
):
    iv = pl.program_id(1)

    @pl.when(iv == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        a_scr[...] = jnp.zeros_like(a_scr)

    logits = jax.lax.dot_general(
        h_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # [block_b, block_v]
    bb = logits.shape[0]
    col = iv * block_v + jax.lax.broadcasted_iota(jnp.int32, (bb, block_v), 1)
    valid = col < vocab
    logits = jnp.where(valid, logits, NEG_INF)

    block_max = jnp.max(logits, axis=1, keepdims=True)  # [bb, 1]
    block_arg = iv * block_v + jnp.argmax(logits, axis=1, keepdims=True).astype(
        jnp.int32
    )

    m_prev = m_scr[:, :1]
    better = block_max > m_prev
    m_new = jnp.maximum(m_prev, block_max)
    p_sum = jnp.sum(jnp.exp(logits - m_new), axis=1, keepdims=True)
    l_scr[...] = jnp.broadcast_to(
        l_scr[:, :1] * jnp.exp(m_prev - m_new) + p_sum, l_scr.shape
    )
    a_scr[...] = jnp.broadcast_to(
        jnp.where(better, block_arg, a_scr[:, :1]), a_scr.shape
    )
    m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)

    @pl.when(iv == num_v_blocks - 1)
    def _emit():
        l = l_scr[:, 0]
        conf_ref[...] = 1.0 / jnp.where(l > 0.0, l, 1.0)
        idx_ref[...] = a_scr[:, 0]


@functools.partial(
    jax.jit, static_argnames=("block_b", "block_v", "interpret")
)
def exit_confidence(
    h: jnp.ndarray,  # [B, d]
    w: jnp.ndarray,  # [d, V]
    *,
    block_b: int = 128,
    block_v: int = 1024,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (top1 softmax prob [B] f32, argmax [B] i32)."""
    B, d = h.shape
    V = w.shape[1]
    block_b = min(block_b, B)
    block_v = min(block_v, V)
    b_pad = (-B) % block_b
    v_pad = (-V) % block_v
    if b_pad:
        h = jnp.pad(h, ((0, b_pad), (0, 0)))
    if v_pad:
        w = jnp.pad(w, ((0, 0), (0, v_pad)))
    nb = (B + b_pad) // block_b
    nv = (V + v_pad) // block_v

    kernel = functools.partial(
        _exit_kernel, block_v=block_v, vocab=V, num_v_blocks=nv
    )
    conf, idx = pl.pallas_call(
        kernel,
        grid=(nb, nv),
        in_specs=[
            pl.BlockSpec((block_b, d), lambda ib, iv: (ib, 0)),
            pl.BlockSpec((d, block_v), lambda ib, iv: (0, iv)),
        ],
        out_specs=[
            pl.BlockSpec((block_b,), lambda ib, iv: (ib,)),
            pl.BlockSpec((block_b,), lambda ib, iv: (ib,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B + b_pad,), jnp.float32),
            jax.ShapeDtypeStruct((B + b_pad,), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_b, 128), jnp.float32),
            pltpu.VMEM((block_b, 128), jnp.float32),
            pltpu.VMEM((block_b, 128), jnp.int32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="exit_confidence",
    )(h, w)
    return conf[:B], idx[:B]
