"""Dispatching wrappers around the Pallas kernels.

Each op picks an implementation:
  * "pallas"            — compiled Pallas kernel (TPU).
  * "pallas_interpret"  — kernel body interpreted in Python (CPU validation).
  * "xla"               — pure-jnp path, GSPMD-shardable; what the CPU-hosted
                          dry-run lowers.

Default: pallas on TPU backends, xla elsewhere.  ``set_backend`` overrides
(tests force "pallas_interpret" to exercise the kernel bodies).
"""
from __future__ import annotations

from typing import Literal

import jax
import jax.numpy as jnp

from repro.kernels import decode_attention as _dec
from repro.kernels import exit_confidence as _exit
from repro.kernels import flash_attention as _flash
from repro.kernels import paged_decode_attention as _paged
from repro.kernels import ref

Backend = Literal["auto", "pallas", "pallas_interpret", "xla"]

_backend: Backend = "auto"


def set_backend(backend: Backend) -> None:
    global _backend
    _backend = backend


def get_backend() -> str:
    if _backend != "auto":
        return _backend
    return "pallas" if jax.default_backend() == "tpu" else "xla"


# ---------------------------------------------------------------------------


def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int | None = None,
    block_q: int = 128,
    block_k: int = 128,
) -> jnp.ndarray:
    be = get_backend()
    if be == "xla":
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    return _flash.flash_attention(
        q,
        k,
        v,
        causal=causal,
        window=window,
        block_q=block_q,
        block_k=block_k,
        interpret=(be == "pallas_interpret"),
    )


def decode_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    lengths: jnp.ndarray,
    *,
    block_k: int = 512,
) -> jnp.ndarray:
    be = get_backend()
    if be == "xla":
        return ref.decode_attention_ref(q, k, v, lengths)
    return _dec.decode_attention(
        q, k, v, lengths, block_k=block_k, interpret=(be == "pallas_interpret")
    )


def paged_decode_attention(
    q: jnp.ndarray,
    k_pool: jnp.ndarray,
    v_pool: jnp.ndarray,
    table: jnp.ndarray,
    lengths: jnp.ndarray,
    *,
    seq_len: int | None = None,
) -> jnp.ndarray:
    """Flash decode through a block table over a paged KV pool.

    The xla path gathers the row's blocks into a contiguous virtual cache
    sliced to ``seq_len`` — the exact shape of the dense slot path, so paged
    and dense decode stay bitwise identical.  The Pallas path streams pool
    blocks via scalar-prefetched table indices and never materializes the
    gather.
    """
    be = get_backend()
    if be == "xla":
        return ref.paged_decode_attention_ref(
            q, k_pool, v_pool, table, lengths, seq_len=seq_len
        )
    if seq_len is not None:
        # the kernel masks by per-row lengths only; clamping reproduces the
        # oracle's slice-to-seq_len semantics on every backend
        lengths = jnp.minimum(lengths, seq_len)
    return _paged.paged_decode_attention(
        q, k_pool, v_pool, table, lengths, interpret=(be == "pallas_interpret")
    )


def exit_confidence(
    h: jnp.ndarray,
    w: jnp.ndarray,
    *,
    block_b: int = 128,
    block_v: int = 1024,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    be = get_backend()
    if be == "xla":
        return ref.exit_confidence_ref(h, w)
    return _exit.exit_confidence(
        h,
        w,
        block_b=block_b,
        block_v=block_v,
        interpret=(be == "pallas_interpret"),
    )
