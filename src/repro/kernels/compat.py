"""Version shims for Pallas TPU APIs.

jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams``; the
kernels import the alias from here so they compile against either side of
the rename.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
