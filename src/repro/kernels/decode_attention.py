"""Pallas TPU flash-decode: one query token vs. a long KV cache.

Decode attention is memory-bound (arithmetic intensity ~1 FLOP/byte: each
cached (k, v) element is read once per step), so the kernel's job is to
stream the KV cache HBM -> VMEM at full bandwidth while keeping the online
softmax state in registers/VMEM:

  * grid = (batch, kv_heads, kv_blocks); last axis sequential, carrying
    (m, l, acc) scratch across the cache walk.
  * all ``groups`` q heads of a kv head are processed together — the score
    matmul is [groups, hd] x [hd, block_k], amortizing each streamed KV
    block over the whole GQA group (the same reuse trick MQA serving uses).
  * per-row validity comes from ``lengths`` (SMEM scalar per batch row), so
    ragged batches share one compiled kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG_INF = -1e30


def _decode_kernel(
    len_ref,  # SMEM [1] i32
    q_ref,  # [1, G, hd]
    k_ref,  # [1, block_k, 1, hd]
    v_ref,  # [1, block_k, 1, hd]
    o_ref,  # [1, G, hd]
    m_scr,  # [G, 128] f32
    l_scr,  # [G, 128] f32
    acc_scr,  # [G, hd] f32
    *,
    sm_scale: float,
    block_k: int,
    num_kv_blocks: int,
):
    ik = pl.program_id(2)
    length = len_ref[0]

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    k_start = ik * block_k

    @pl.when(k_start < length)
    def _compute():
        q = q_ref[0]  # [G, hd]
        k = k_ref[0, :, 0, :]  # [block_k, hd]
        v = v_ref[0, :, 0, :]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # [G, block_k]
        s = s * sm_scale
        G = s.shape[0]
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (G, block_k), 1)
        mask = k_pos < length
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[:, :1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = jnp.broadcast_to(
            l_scr[:, :1] * alpha + jnp.sum(p, axis=1, keepdims=True), l_scr.shape
        )
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        acc_scr[...] = acc_scr[...] * alpha + pv
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)

    @pl.when(ik == num_kv_blocks - 1)
    def _emit():
        l = l_scr[:, :1]
        o_ref[0] = (acc_scr[...] / jnp.where(l > 0.0, l, 1.0)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_k", "sm_scale", "interpret")
)
def decode_attention(
    q: jnp.ndarray,  # [B, Hq, hd]
    k: jnp.ndarray,  # [B, S, KVH, hd]
    v: jnp.ndarray,  # [B, S, KVH, hd]
    lengths: jnp.ndarray,  # [B] i32 — valid prefix of each cache row
    *,
    block_k: int = 512,
    sm_scale: float | None = None,
    interpret: bool = False,
) -> jnp.ndarray:
    B, Hq, hd = q.shape
    S, KVH = k.shape[1], k.shape[2]
    if Hq % KVH != 0:
        raise ValueError(f"q heads {Hq} not a multiple of kv heads {KVH}")
    G = Hq // KVH
    if sm_scale is None:
        sm_scale = float(1.0 / np.sqrt(hd))

    block_k = min(block_k, S)
    k_pad = (-S) % block_k
    if k_pad:
        k = jnp.pad(k, ((0, 0), (0, k_pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, k_pad), (0, 0), (0, 0)))
    nk = (S + k_pad) // block_k

    # q regrouped so each kv head's G query heads are contiguous
    q3 = q.reshape(B, KVH, G, hd).reshape(B, KVH * G, hd)

    kernel = functools.partial(
        _decode_kernel, sm_scale=sm_scale, block_k=block_k, num_kv_blocks=nk
    )
    out = pl.pallas_call(
        kernel,
        grid=(B, KVH, nk),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, ik: (b,), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, G, hd), lambda b, h, ik: (b, h, 0)),
            pl.BlockSpec((1, block_k, 1, hd), lambda b, h, ik: (b, ik, h, 0)),
            pl.BlockSpec((1, block_k, 1, hd), lambda b, h, ik: (b, ik, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, hd), lambda b, h, ik: (b, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 128), jnp.float32),
            pltpu.VMEM((G, 128), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="decode_attention",
    )(lengths.astype(jnp.int32), q3, k, v)
    return out.reshape(B, KVH, G, hd).reshape(B, Hq, hd)
