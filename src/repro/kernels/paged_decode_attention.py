"""Pallas TPU flash-decode over a PAGED KV cache (vLLM-style block pool).

Same online-softmax walk as ``repro.kernels.decode_attention``, but the KV
cache is a physical block pool ``[num_blocks, block_size, kv_heads, hd]``
addressed through a per-row block table ``[B, n_logical]`` instead of a
contiguous ``[B, S, ...]`` arena.  The table rides in as a scalar-prefetch
operand (SMEM before the body runs), so the k/v ``BlockSpec`` index maps can
dereference it: grid step ``(b, h, j)`` DMAs physical block ``table[b, j]``
straight from the pool — the virtual sequence is never materialized in HBM.

  * grid = (batch, kv_heads, n_logical); last axis sequential, carrying the
    (m, l, acc) scratch across the row's block walk.
  * unallocated logical blocks point at the pool's trash row; their
    positions are ``>= lengths[b]`` so the whole tile is skipped (masked and
    ``pl.when``-gated, same as padded tail blocks in the dense kernel).
  * one pool block per grid step: ``block_size`` should be a multiple of
    the lane tiling (128) for peak DMA efficiency on real TPUs; tiny blocks
    work but stream narrow tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG_INF = -1e30


def _paged_decode_kernel(
    table_ref,  # SMEM [B, n_logical] i32 (scalar prefetch)
    len_ref,  # SMEM [B] i32 (scalar prefetch)
    q_ref,  # [1, G, hd]
    k_ref,  # [1, block_size, 1, hd] — physical block table_ref[b, j]
    v_ref,  # [1, block_size, 1, hd]
    o_ref,  # [1, G, hd]
    m_scr,  # [G, 128] f32
    l_scr,  # [G, 128] f32
    acc_scr,  # [G, hd] f32
    *,
    sm_scale: float,
    block_size: int,
    num_logical: int,
):
    b = pl.program_id(0)
    j = pl.program_id(2)
    length = len_ref[b]

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    k_start = j * block_size

    @pl.when(k_start < length)
    def _compute():
        q = q_ref[0]  # [G, hd]
        k = k_ref[0, :, 0, :]  # [block_size, hd]
        v = v_ref[0, :, 0, :]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # [G, block_size]
        s = s * sm_scale
        G = s.shape[0]
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (G, block_size), 1)
        mask = k_pos < length
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[:, :1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = jnp.broadcast_to(
            l_scr[:, :1] * alpha + jnp.sum(p, axis=1, keepdims=True), l_scr.shape
        )
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        acc_scr[...] = acc_scr[...] * alpha + pv
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)

    @pl.when(j == num_logical - 1)
    def _emit():
        l = l_scr[:, :1]
        o_ref[0] = (acc_scr[...] / jnp.where(l > 0.0, l, 1.0)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("sm_scale", "interpret"))
def paged_decode_attention(
    q: jnp.ndarray,  # [B, Hq, hd]
    k_pool: jnp.ndarray,  # [NB, bs, KVH, hd]
    v_pool: jnp.ndarray,  # [NB, bs, KVH, hd]
    table: jnp.ndarray,  # [B, n_logical] i32
    lengths: jnp.ndarray,  # [B] i32 — valid prefix of each row
    *,
    sm_scale: float | None = None,
    interpret: bool = False,
) -> jnp.ndarray:
    B, Hq, hd = q.shape
    bs, KVH = k_pool.shape[1], k_pool.shape[2]
    if Hq % KVH != 0:
        raise ValueError(f"q heads {Hq} not a multiple of kv heads {KVH}")
    G = Hq // KVH
    n_logical = table.shape[1]
    if sm_scale is None:
        sm_scale = float(1.0 / np.sqrt(hd))

    # q regrouped so each kv head's G query heads are contiguous
    q3 = q.reshape(B, KVH, G, hd).reshape(B, KVH * G, hd)

    kernel = functools.partial(
        _paged_decode_kernel,
        sm_scale=sm_scale,
        block_size=bs,
        num_logical=n_logical,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # block table + lengths land in SMEM up front
        grid=(B, KVH, n_logical),
        in_specs=[
            pl.BlockSpec((1, G, hd), lambda b, h, j, table_ref, len_ref: (b, h, 0)),
            pl.BlockSpec(
                (1, bs, 1, hd),
                lambda b, h, j, table_ref, len_ref: (table_ref[b, j], 0, h, 0),
            ),
            pl.BlockSpec(
                (1, bs, 1, hd),
                lambda b, h, j, table_ref, len_ref: (table_ref[b, j], 0, h, 0),
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, G, hd), lambda b, h, j, table_ref, len_ref: (b, h, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((G, 128), jnp.float32),
            pltpu.VMEM((G, 128), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hq, hd), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="paged_decode_attention",
    )(table.astype(jnp.int32), lengths.astype(jnp.int32), q3, k_pool, v_pool)
    return out.reshape(B, KVH, G, hd).reshape(B, Hq, hd)
