"""Pallas TPU kernels for the data-plane hot spots.

Three kernels (DESIGN.md §5), each with a pure-jnp oracle in ref.py and a
dispatching wrapper in ops.py:

  flash_attention   — prefill attention, online softmax over KV blocks
  decode_attention  — one query vs. a long KV cache (flash-decode)
  exit_confidence   — the paper-specific head: fused (max softmax, argmax)
                      over a vocab-blocked matmul, never materializing the
                      [batch, vocab] logits in HBM
"""
