"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


def flash_attention_ref(
    q: jnp.ndarray,  # [B, Sq, Hq, hd]
    k: jnp.ndarray,  # [B, Sk, kv, hd]
    v: jnp.ndarray,  # [B, Sk, kv, hd]
    causal: bool = True,
    window: int | None = None,
) -> jnp.ndarray:
    B, Sq, Hq, hd = q.shape
    kvh = k.shape[2]
    G = Hq // kvh
    qg = q.reshape(B, Sq, kvh, G, hd)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32)
    scores = scores / np.sqrt(hd)
    q_pos = jnp.arange(Sq)
    k_pos = jnp.arange(k.shape[1])
    mask = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        mask = mask & (q_pos[:, None] >= k_pos[None, :])
    if window is not None:
        mask = mask & (k_pos[None, :] > q_pos[:, None] - window)
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
    return out.reshape(B, Sq, Hq, hd)


def decode_attention_ref(
    q: jnp.ndarray,  # [B, Hq, hd]
    k: jnp.ndarray,  # [B, S, kv, hd]
    v: jnp.ndarray,  # [B, S, kv, hd]
    lengths: jnp.ndarray,  # [B] valid prefix length of each cache row
) -> jnp.ndarray:
    B, Hq, hd = q.shape
    kvh = k.shape[2]
    G = Hq // kvh
    qg = q.reshape(B, kvh, G, hd)
    # * (1/sqrt) rather than /sqrt: bitwise-identical to the Pallas kernel's
    # ``s * sm_scale`` and to the q-chunked prefill path (_attend_block)
    scores = jnp.einsum("bkgd,bskd->bkgs", qg, k).astype(jnp.float32) * float(
        1.0 / np.sqrt(hd)
    )
    valid = jnp.arange(k.shape[1])[None, :] < lengths[:, None]  # [B, S]
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgs,bskd->bkgd", probs, v)
    return out.reshape(B, Hq, hd)


def paged_decode_attention_ref(
    q: jnp.ndarray,  # [B, Hq, hd]
    k_pool: jnp.ndarray,  # [NB, bs, kv, hd] physical block pool
    v_pool: jnp.ndarray,  # [NB, bs, kv, hd]
    table: jnp.ndarray,  # [B, n_logical] i32 — physical block per logical block
    lengths: jnp.ndarray,  # [B] valid prefix length of each row
    seq_len: int | None = None,
) -> jnp.ndarray:
    """Oracle: gather each row's blocks into a contiguous virtual cache and
    run the dense decode reference on it.

    ``seq_len`` truncates the virtual view (``n_logical * bs`` may overhang
    the real max length); slicing there keeps the softmax reductions the
    exact shape of the dense slot path, so paged decode is bitwise identical
    to it.  Unallocated table entries may point anywhere valid (the trash
    block) — those positions are >= ``lengths`` and masked.
    """
    B = q.shape[0]
    k = k_pool[table].reshape(B, -1, *k_pool.shape[2:])
    v = v_pool[table].reshape(B, -1, *v_pool.shape[2:])
    if seq_len is not None:
        k = k[:, :seq_len]
        v = v[:, :seq_len]
    return decode_attention_ref(q, k, v, lengths)


def exit_confidence_ref(h: jnp.ndarray, w: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """h: [B, d], w: [d, V] -> (top-1 softmax prob [B] f32, argmax [B] i32).

    Matmul accumulates in f32, matching the kernel's MXU accumulation.
    """
    logits = jnp.matmul(
        h, w.astype(h.dtype), preferred_element_type=jnp.float32
    )
    m = jnp.max(logits, axis=-1)
    l = jnp.sum(jnp.exp(logits - m[:, None]), axis=-1)
    conf = 1.0 / l
    idx = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return conf, idx
