"""Pallas TPU flash attention (prefill): online softmax over KV blocks.

TPU-native design (not a CUDA port):
  * grid = (batch, q_heads, q_blocks, kv_blocks); the LAST axis is the
    sequential ("arbitrary") one, so the (m, l, acc) running state lives in
    VMEM scratch across kv blocks — the TPU analogue of a CUDA thread-block
    loop, but driven by the Mosaic pipeline, with q/k/v tiles DMA'd
    HBM -> VMEM ahead of compute.
  * Q tile (block_q x head_dim) stays resident in VMEM for a whole row of
    kv blocks; K/V tiles stream through.  Matmul dims are MXU-aligned
    (block sizes multiples of 128, head_dim 128 for every assigned arch).
  * GQA folds into the index map: q head h reads kv head h // groups — no
    KV replication in HBM.
  * Causal + sliding-window masking skip *entire* kv blocks via pl.when
    (the block-diagonal walk), and mask within the two boundary blocks.

Forward-only: the serving data plane (prefill) is where the paper's delay
model spends its alpha_h; training uses the XLA chunked path which autodiffs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG_INF = -1e30


def _flash_kernel(
    q_ref,  # [1, block_q, 1, hd]
    k_ref,  # [1, block_k, 1, hd]
    v_ref,  # [1, block_k, 1, hd]
    o_ref,  # [1, block_q, 1, hd]
    m_scr,  # [block_q, 128] f32
    l_scr,  # [block_q, 128] f32
    acc_scr,  # [block_q, hd] f32
    *,
    sm_scale: float,
    causal: bool,
    window: int | None,
    block_q: int,
    block_k: int,
    kv_len: int,
    num_kv_blocks: int,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = iq * block_q
    k_start = ik * block_k

    # --- whole-block skip test (static against traced block indices) -------
    live = k_start < kv_len  # padded tail blocks
    if causal:
        live = jnp.logical_and(live, k_start <= q_start + block_q - 1)
    if window is not None:
        live = jnp.logical_and(live, k_start + block_k - 1 > q_start - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, :, 0, :]  # [block_q, hd]
        k = k_ref[0, :, 0, :]  # [block_k, hd]
        v = v_ref[0, :, 0, :]
        s = jax.lax.dot_general(
            q,
            k,
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # [block_q, block_k]
        s = s * sm_scale

        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = k_pos < kv_len
        if causal:
            mask = jnp.logical_and(mask, q_pos >= k_pos)
        if window is not None:
            mask = jnp.logical_and(mask, k_pos > q_pos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[:, :1]  # [block_q, 1]
        block_max = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, block_max)
        # exp shift; fully-masked rows keep m == NEG_INF and p == 0
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)

        l_new = l_scr[:, :1] * alpha + jnp.sum(p, axis=1, keepdims=True)
        pv = jax.lax.dot_general(
            p.astype(v.dtype),
            v,
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # [block_q, hd]
        acc_scr[...] = acc_scr[...] * alpha + pv
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(ik == num_kv_blocks - 1)
    def _emit():
        l = l_scr[:, :1]
        out = acc_scr[...] / jnp.where(l > 0.0, l, 1.0)
        o_ref[0, :, 0, :] = out.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal",
        "window",
        "block_q",
        "block_k",
        "sm_scale",
        "interpret",
    ),
)
def flash_attention(
    q: jnp.ndarray,  # [B, Sq, Hq, hd]
    k: jnp.ndarray,  # [B, Sk, KVH, hd]
    v: jnp.ndarray,  # [B, Sk, KVH, hd]
    *,
    causal: bool = True,
    window: int | None = None,
    block_q: int = 128,
    block_k: int = 128,
    sm_scale: float | None = None,
    interpret: bool = False,
) -> jnp.ndarray:
    B, Sq, Hq, hd = q.shape
    Sk, KVH = k.shape[1], k.shape[2]
    if Hq % KVH != 0:
        raise ValueError(f"q heads {Hq} not a multiple of kv heads {KVH}")
    groups = Hq // KVH
    if sm_scale is None:
        sm_scale = float(1.0 / np.sqrt(hd))

    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    q_pad = (-Sq) % block_q
    k_pad = (-Sk) % block_k
    if q_pad:
        q = jnp.pad(q, ((0, 0), (0, q_pad), (0, 0), (0, 0)))
    if k_pad:
        k = jnp.pad(k, ((0, 0), (0, k_pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, k_pad), (0, 0), (0, 0)))
    nq = (Sq + q_pad) // block_q
    nk = (Sk + k_pad) // block_k

    kernel = functools.partial(
        _flash_kernel,
        sm_scale=sm_scale,
        causal=causal,
        window=window,
        block_q=block_q,
        block_k=block_k,
        kv_len=Sk,
        num_kv_blocks=nk,
    )
    out = pl.pallas_call(
        kernel,
        grid=(B, Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, 1, hd), lambda b, h, iq, ik: (b, iq, h, 0)),
            pl.BlockSpec(
                (1, block_k, 1, hd), lambda b, h, iq, ik, g=groups: (b, ik, h // g, 0)
            ),
            pl.BlockSpec(
                (1, block_k, 1, hd), lambda b, h, iq, ik, g=groups: (b, ik, h // g, 0)
            ),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, hd), lambda b, h, iq, ik: (b, iq, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sq + q_pad, Hq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="flash_attention",
    )(q, k, v)
    if q_pad:
        out = out[:, :Sq]
    return out
