from repro.data.pipeline import DataConfig, RequestConfig, poisson_requests, token_stream

__all__ = ["DataConfig", "RequestConfig", "poisson_requests", "token_stream"]
