"""Synthetic shard-aware data pipeline + Poisson request generator.

Training: an infinite deterministic token stream (seeded, reproducible
across restarts — the checkpoint records the step, the pipeline reseeds
from it, so resume is bit-exact without storing cursor state).  The batch
is produced already sharded over the mesh's batch axes via
``jax.make_array_from_callback`` when a mesh is installed.

Serving: Poisson arrivals of classification/prompt requests (the paper's
task model), with prompt lengths drawn from a lognormal.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Iterator

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    batch_size: int = 8
    seq_len: int = 512
    seed: int = 0


def _batch_for_step(cfg: ArchConfig, dcfg: DataConfig, step: int) -> dict:
    """Deterministic synthetic LM batch for a given step (host-side numpy)."""
    rng = np.random.default_rng((dcfg.seed, step))
    B, S = dcfg.batch_size, dcfg.seq_len
    if cfg.frontend == "embeds":
        embeds = rng.standard_normal((B, S, cfg.d_model)).astype(np.float32) * 0.02
        labels = rng.integers(0, cfg.vocab_size, size=(B, S)).astype(np.int32)
        return {"embeds": embeds, "labels": labels}
    # Markov-ish stream so the LM loss has learnable structure
    base = rng.integers(0, cfg.vocab_size, size=(B, S)).astype(np.int32)
    shifted = np.roll(base, 1, axis=1)
    mix = rng.random((B, S)) < 0.5
    tokens = np.where(mix, base, (shifted * 31 + 7) % cfg.vocab_size).astype(np.int32)
    labels = np.roll(tokens, -1, axis=1).astype(np.int32)
    labels[:, -1] = -1  # mask the wrap-around position
    return {"tokens": tokens, "labels": labels}


def token_stream(
    cfg: ArchConfig,
    dcfg: DataConfig,
    start_step: int = 0,
    mesh: Mesh | None = None,
) -> Iterator[dict]:
    """Infinite stream of batches, device-put with batch sharding if a mesh
    is given (data arrives sharded; no host-side global concat)."""
    step = start_step
    batch_spec = None
    if mesh is not None:
        axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        batch_spec = P(axes if len(axes) > 1 else axes[0])
    while True:
        host = _batch_for_step(cfg, dcfg, step)
        if mesh is None:
            yield {k: jnp.asarray(v) for k, v in host.items()}
        else:
            out = {}
            for k, v in host.items():
                sh = NamedSharding(mesh, P(*([batch_spec[0]] + [None] * (v.ndim - 1))))
                out[k] = jax.make_array_from_callback(
                    v.shape, sh, lambda idx, v=v: v[idx]
                )
            yield out
        step += 1


@dataclasses.dataclass(frozen=True)
class RequestConfig:
    arrival_rate: float = 20.0  # tasks/s across the system
    mean_prompt_len: int = 64
    sigma: float = 0.4
    seed: int = 0


def poisson_requests(
    cfg: ArchConfig, rcfg: RequestConfig, duration: float
) -> list[tuple[float, np.ndarray]]:
    """[(arrival_time, prompt_tokens)] over ``duration`` seconds."""
    rng = np.random.default_rng(rcfg.seed)
    out = []
    t = rng.exponential(1.0 / rcfg.arrival_rate)
    while t < duration:
        n = max(2, int(rng.lognormal(np.log(rcfg.mean_prompt_len), rcfg.sigma)))
        prompt = rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
        out.append((float(t), prompt))
        t += rng.exponential(1.0 / rcfg.arrival_rate)
    return out
