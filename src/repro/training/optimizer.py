"""AdamW in pure JAX, with optimizer state sharded like the parameters.

The state tree mirrors the param tree leaf-for-leaf ({"m": ..., "v": ...}),
so ``sharding.param_specs`` applies unchanged — on the (data, model) mesh
this is ZeRO-style sharding of (m, v) over the fsdp axis for free.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    learning_rate: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def init_opt_state(params: Any) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def lr_schedule(step: jnp.ndarray, cfg: AdamWConfig) -> jnp.ndarray:
    """Linear warmup then cosine decay to min_lr_ratio."""
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    decay = cfg.min_lr_ratio + (1.0 - cfg.min_lr_ratio) * cos
    return cfg.learning_rate * warm * decay


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(l.astype(jnp.float32))) for l in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: Any, max_norm: float) -> tuple[Any, jnp.ndarray]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def adamw_update(
    params: Any, grads: Any, state: dict, cfg: AdamWConfig
) -> tuple[Any, dict, dict]:
    """One AdamW step; returns (params', state', metrics)."""
    if cfg.grad_clip_norm > 0:
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip_norm)
    else:
        gnorm = global_norm(grads)
    step = state["step"] + 1
    lr = lr_schedule(step, cfg)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * jnp.square(g)
        m_hat = m_new / bc1
        v_hat = v_new / bc2
        delta = m_hat / (jnp.sqrt(v_hat) + cfg.eps)
        if cfg.weight_decay > 0 and p.ndim >= 2:  # decay matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    params_new = treedef.unflatten([o[0] for o in out])
    m_new = treedef.unflatten([o[1] for o in out])
    v_new = treedef.unflatten([o[2] for o in out])
    state_new = {"m": m_new, "v": v_new, "step": step}
    return params_new, state_new, {"grad_norm": gnorm, "lr": lr}
