"""train_step factory: microbatched, remat'd, sharded loss/grad/update.

``make_train_step(cfg, opt_cfg, microbatches=k)`` returns a function
``(params, opt_state, batch) -> (params', opt_state', metrics)`` suitable
for ``jax.jit`` with in/out shardings from ``sharding.param_specs``:

  * the global batch is split into k microbatches scanned sequentially,
    gradients accumulated in f32 — the standard memory/throughput knob
    (remat already bounds activation memory inside each stage scan);
  * optional int8 cross-pod gradient compression (``compress_pod_axis``):
    gradients are reduced in two hops — GSPMD handles the intra-pod
    reduction implicitly (batch sharded over "data"), while the slow
    cross-pod hop runs through the int8 codec inside a partial-auto
    shard_map over the "pod" axis with error feedback carried in the
    optimizer state.
"""
from __future__ import annotations

import functools
import os
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import model as model_lib
from repro.sharding import specs as sharding_specs
from repro.training import optimizer as opt_lib


def _split_microbatches(batch: dict, k: int) -> dict:
    def resh(x):
        b = x.shape[0]
        assert b % k == 0, f"batch {b} not divisible by microbatches {k}"
        return x.reshape(k, b // k, *x.shape[1:])

    return jax.tree.map(resh, batch)


def accumulate_grads(
    loss_fn: Callable, params: Any, batch: dict, k: int
) -> tuple[jnp.ndarray, Any, dict]:
    """Scan over k microbatches; returns (loss, grads, metrics) averaged."""
    if k <= 1:
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch
        )
        return loss, grads, metrics

    mb = _split_microbatches(batch, k)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def body(carry, mb_batch):
        acc, loss_sum = carry
        (loss, metrics), grads = grad_fn(params, mb_batch)
        acc = jax.tree.map(
            lambda a, g: a + g.astype(jnp.float32) / k, acc, grads
        )
        return (acc, loss_sum + loss / k), metrics

    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (grads, loss), metrics = jax.lax.scan(body, (zeros, 0.0), mb)
    metrics = jax.tree.map(lambda m: m.mean(), metrics)
    return loss, grads, metrics


def _cast_matrices(params: Any, dtype) -> Any:
    """bf16 compute copy of the f32 master weights (cast on the LOCAL shard,
    so FSDP weight all-gathers move half the bytes).  1-D leaves (norm
    scales, biases) stay f32."""
    return jax.tree.map(
        lambda p: p.astype(dtype)
        if (p.dtype == jnp.float32 and p.ndim >= 2)
        else p,
        params,
    )


def make_train_step(
    cfg: ArchConfig,
    opt_cfg: opt_lib.AdamWConfig | None = None,
    microbatches: int = 1,
    loss_fn: Callable | None = None,
):
    opt_cfg = opt_cfg or opt_lib.AdamWConfig()
    inner_loss = loss_fn or (lambda p, b: model_lib.loss_fn(p, b, cfg))
    if os.environ.get("REPRO_BF16_PARAMS", "0") == "1":
        base_loss = lambda p, b: inner_loss(_cast_matrices(p, cfg.dtype), b)
    else:
        base_loss = inner_loss

    def train_step(params: Any, opt_state: dict, batch: dict):
        loss, grads, metrics = accumulate_grads(
            base_loss, params, batch, microbatches
        )
        # ZeRO-2 hint: pin gradient sharding to the param layout so the
        # cross-data reduction lowers as reduce-scatter, not all-reduce.
        # Gated so the perf iteration can record before/after cleanly.
        if os.environ.get("REPRO_GRAD_RS", "0") == "1":
            grads = sharding_specs.constrain_like_params(grads)
        params_new, opt_new, opt_metrics = opt_lib.adamw_update(
            params, grads, opt_state, opt_cfg
        )
        metrics = dict(metrics, **opt_metrics)
        return params_new, opt_new, metrics

    return train_step


def make_compressed_train_step(
    cfg: ArchConfig,
    mesh,
    opt_cfg: opt_lib.AdamWConfig | None = None,
    microbatches: int = 1,
):
    """Cross-pod int8 gradient reduction (beyond-paper §Perf optimization).

    Requires a mesh with a "pod" axis.  The batch arrives sharded over
    ("pod", "data"); inside a partial-auto shard_map over "pod", each pod
    computes its own (intra-pod-reduced, GSPMD) gradients, quantizes them
    with error feedback, and psums int8 over the pod axis — 4x less DCN
    traffic than an f32 all-reduce.
    """
    from jax.sharding import PartitionSpec as P

    from repro.runtime import compression

    opt_cfg = opt_cfg or opt_lib.AdamWConfig()
    base_loss = lambda p, b: model_lib.loss_fn(p, b, cfg)
    npods = mesh.shape["pod"]
    other_axes = frozenset(n for n in mesh.axis_names if n != "pod")

    def train_step(params: Any, opt_state: dict, batch: dict):
        error = opt_state["error"]

        @functools.partial(
            jax.shard_map,
            mesh=mesh,
            in_specs=(P(), P(), P("pod"), P()),
            out_specs=(P(), P(), P(), P()),
            check_vma=False,
            axis_names=frozenset({"pod"}),
        )
        def pod_grads(params, error, batch, _dummy):
            loss, grads, metrics = accumulate_grads(
                base_loss, params, batch, microbatches
            )
            q, s, err_new = compression.compress_tree(grads, error)
            # int8 payload crosses DCN; accumulate in int32 to avoid overflow
            q_sum = jax.tree.map(
                lambda x: jax.lax.psum(x.astype(jnp.int32), "pod"), q
            )
            s_max = jax.tree.map(lambda x: jax.lax.pmax(x, "pod"), s)
            grads_global = jax.tree.map(
                lambda qi, si: qi.astype(jnp.float32) * si / npods, q_sum, s_max
            )
            loss = jax.lax.pmean(loss, "pod")
            metrics = jax.tree.map(lambda m: jax.lax.pmean(m, "pod"), metrics)
            return grads_global, err_new, loss, metrics

        grads, err_new, loss, metrics = pod_grads(
            params, error, batch, jnp.zeros(())
        )
        params_new, opt_new, opt_metrics = opt_lib.adamw_update(
            params, grads, opt_state, opt_cfg
        )
        opt_new["error"] = err_new
        return params_new, opt_new, dict(metrics, **opt_metrics)

    return train_step
