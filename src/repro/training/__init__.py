from repro.training.optimizer import AdamWConfig, adamw_update, init_opt_state
from repro.training.train_step import make_train_step, make_compressed_train_step

__all__ = [
    "AdamWConfig", "adamw_update", "init_opt_state",
    "make_train_step", "make_compressed_train_step",
]
