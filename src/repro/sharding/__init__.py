from repro.sharding.specs import (
    MeshRules,
    activation_spec,
    batch_specs,
    cache_specs,
    clear_mesh,
    constrain,
    get_mesh,
    param_specs,
    set_mesh,
)

__all__ = [
    "MeshRules",
    "activation_spec",
    "batch_specs",
    "cache_specs",
    "clear_mesh",
    "constrain",
    "get_mesh",
    "param_specs",
    "set_mesh",
]
