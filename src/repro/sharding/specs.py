"""Logical-axis sharding rules (DP/TP/SP/EP) with divisibility fallbacks.

Models are written as global math; this module decides layouts:

  * ``param_specs(cfg, params)`` — a PartitionSpec pytree for the parameter
    pytree, keyed off leaf path names (w_q/w_down/embed/...).  2-D weights
    get (fsdp, tp) or (tp, fsdp); stacked scan layers get a leading None.
  * ``constrain(x, *logical)`` — with_sharding_constraint by logical axis
    names ("batch", "seq", "tp", ...), silently a no-op when no mesh is
    installed (unit tests) or when a dim isn't divisible by the axis size.

Logical axes:
  batch -> ("pod", "data") when the mesh has a pod axis, else ("data",)
  fsdp  -> "data"   (ZeRO/FSDP weight + optimizer-state sharding)
  tp    -> "model"  (tensor parallel)
  seq   -> "model"  (Megatron-style sequence parallelism of the residual
                     stream between blocks; attention/FFN internals are
                     free for GSPMD to all-gather)
"""
from __future__ import annotations

import dataclasses
import re
import threading
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


@dataclasses.dataclass(frozen=True)
class MeshRules:
    mesh: Mesh
    batch_axes: tuple[str, ...]
    fsdp_axis: str | None
    tp_axis: str | None

    @classmethod
    def standard(cls, mesh: Mesh) -> "MeshRules":
        names = mesh.axis_names
        batch = tuple(a for a in ("pod", "data") if a in names)
        return cls(
            mesh=mesh,
            batch_axes=batch or (names[0],),
            fsdp_axis="data" if "data" in names else None,
            tp_axis="model" if "model" in names else None,
        )

    def as_serving(self) -> "MeshRules":
        """Inference layout: weights TP-sharded only, REPLICATED across the
        data axis (no FSDP).  Decode reads every weight every step; an
        FSDP layout would all-gather the whole model per token (measured:
        2 TB/step on qwen2.5-32b decode_32k)."""
        import dataclasses as _dc

        return _dc.replace(self, fsdp_axis=None)

    @classmethod
    def pure_dp(cls, mesh: Mesh) -> "MeshRules":
        """All mesh axes act as data parallelism; no tensor parallelism.
        The right policy for models far smaller than the pod (e.g. a 350M
        xLSTM on 256 chips): weights replicate, every chip gets its own
        batch rows, the only collective left is the gradient reduction."""
        names = mesh.axis_names
        batch = tuple(a for a in ("pod", "data", "model") if a in names)
        return cls(
            mesh=mesh,
            batch_axes=batch or tuple(names),
            fsdp_axis="data" if "data" in names else None,
            tp_axis=None,
        )

    def axis_size(self, axis: str | tuple[str, ...] | None) -> int:
        if axis is None:
            return 1
        if isinstance(axis, str):
            axis = (axis,)
        return int(np.prod([self.mesh.shape[a] for a in axis]))

    def resolve(self, logical: str | None):
        if logical is None:
            return None
        if logical == "batch":
            return self.batch_axes if len(self.batch_axes) > 1 else self.batch_axes[0]
        if logical == "fsdp":
            return self.fsdp_axis
        if logical in ("tp", "seq", "vocab"):
            return self.tp_axis
        raise ValueError(f"unknown logical axis {logical!r}")


def set_mesh(mesh: Mesh, policy: str = "dp_tp") -> MeshRules:
    if policy == "pure_dp":
        rules = MeshRules.pure_dp(mesh)
    elif policy == "dp_tp":
        rules = MeshRules.standard(mesh)
    else:
        raise ValueError(f"unknown sharding policy {policy!r}")
    _state.rules = rules
    return rules


def get_mesh() -> MeshRules | None:
    return getattr(_state, "rules", None)


def clear_mesh() -> None:
    _state.rules = None


# ---------------------------------------------------------------------------
# Activation constraints
# ---------------------------------------------------------------------------


def _spec_for(shape: tuple[int, ...], logical: tuple[str | None, ...], rules: MeshRules) -> P:
    parts = []
    for dim, name in zip(shape, logical):
        axis = rules.resolve(name)
        if axis is None:
            parts.append(None)
            continue
        size = rules.axis_size(axis)
        parts.append(axis if dim % size == 0 and dim >= size else None)
    return P(*parts)


def activation_spec(shape: tuple[int, ...], *logical: str | None) -> P | None:
    rules = get_mesh()
    if rules is None:
        return None
    if len(logical) < len(shape):
        logical = tuple(logical) + (None,) * (len(shape) - len(logical))
    return _spec_for(shape, logical, rules)


def constrain(x, *logical: str | None):
    """Constrain x's sharding by logical names; no-op without an installed mesh."""
    rules = get_mesh()
    if rules is None:
        return x
    spec = activation_spec(x.shape, *logical)
    return jax.lax.with_sharding_constraint(x, NamedSharding(rules.mesh, spec))


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------

# leaf-name -> logical layout for the *trailing* dims (stacked scan dims get
# a leading None automatically).  "in" = (fsdp, tp), "out" = (tp, fsdp).
_IN_PROJ = (
    "w_q|w_k|w_v|w_gate|w_up|up_proj|in_proj|w_if|w_gates|router|w_dkv|w_kpe|"
    "w_uk|w_uv"
)
_OUT_PROJ = "w_o|w_down|down_proj|out_proj"

_RULES: list[tuple[re.Pattern, tuple[str | None, ...]]] = [
    (re.compile(r"embed$"), ("tp", "fsdp")),
    (re.compile(r"lm_head$"), ("fsdp", "tp")),
    (re.compile(rf"({_IN_PROJ})$"), ("fsdp", "tp")),
    (re.compile(rf"({_OUT_PROJ})$"), ("tp", "fsdp")),
    (re.compile(r"(conv_w)$"), (None, "tp")),
    (re.compile(r"(conv_b|b_q|b_k|b_v|if_bias|gate_bias)$"), ("tp",)),
    (re.compile(r"r_gates$"), (None, None, "tp")),
    (re.compile(r"(scale|bias|a_log|d_skip|dt_bias)$"), (None,)),
]


def _leaf_logical(path_str: str, ndim: int) -> tuple[str | None, ...]:
    for pat, layout in _RULES:
        if pat.search(path_str):
            if len(layout) > ndim:
                return layout[-ndim:] if ndim > 0 else ()
            return (None,) * (ndim - len(layout)) + tuple(layout)
    return (None,) * ndim


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def param_specs(params: Any, rules: MeshRules | None = None) -> Any:
    """PartitionSpec pytree for a parameter (or gradient/opt-state) pytree."""
    rules = rules or get_mesh()

    def spec_leaf(path, leaf):
        ps = _path_str(path)
        ndim = len(leaf.shape)
        logical = _leaf_logical(ps, ndim)
        # stacked scan params under stages/: leading dim is the layer stack
        if "stages" in ps and ndim >= 1 and len(logical) == ndim and ndim > 1:
            logical = (None,) + logical[1:]
        if rules is None:
            return P()
        return _spec_for(leaf.shape, logical, rules)

    return jax.tree_util.tree_map_with_path(spec_leaf, params)


def named_shardings(specs: Any, mesh: Mesh) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs)


# ---------------------------------------------------------------------------
# Batch + KV/state cache specs (serving)
# ---------------------------------------------------------------------------


def batch_specs(batch: Any, rules: MeshRules | None = None) -> Any:
    """Shard dim 0 (global batch) over the batch axes, rest replicated."""
    rules = rules or get_mesh()

    def one(leaf):
        if rules is None:
            return P()
        return _spec_for(leaf.shape, ("batch",) + (None,) * (len(leaf.shape) - 1), rules)

    return jax.tree.map(one, batch)


# cache leaf name -> (num_trailing_dims, kind)
#   kind "kv"    : (..., B, S, *rest)  — batch over data, seq over model
#   kind "state" : (..., B, H, *rest)  — batch over data, heads over model
#   kind "convd" : (..., B, K, D)      — batch over data, D over model
#   kind "scalar": replicated
_CACHE_KINDS: dict[str, tuple[int, str]] = {
    "k": (4, "kv"),
    "v": (4, "kv"),
    "c_kv": (3, "kv"),
    "k_pe": (3, "kv"),
    "ssd": (4, "state"),
    "C": (4, "state"),
    "n": (3, "state"),
    "m": (2, "state"),
    "c": (3, "state"),
    "h": (3, "state"),
    "conv": (3, "convd"),
    "pos": (0, "scalar"),
    "slot_pos": (1, "scalar"),
}


def cache_specs(cache: Any, rules: MeshRules | None = None) -> Any:
    """PartitionSpec pytree for decode caches (stacked or unstacked).

    Policy: shard batch over the batch axes and the long dim (sequence for
    KV, heads for recurrent state) over the model axis.  When the batch
    is too small to shard (long-context, batch=1), the sequence dim is
    sharded over (data x model) jointly — the distributed flash-decode
    layout: every chip holds a KV slice, partial softmax + psum combine.
    All choices degrade to replication when a dim isn't divisible.
    """
    rules = rules or get_mesh()

    def leaf_spec(path, leaf):
        if rules is None:
            return P()
        name = None
        for k in reversed(path):
            kk = getattr(k, "key", None)
            if isinstance(kk, str):
                name = kk
                break
        shape = leaf.shape
        nd = len(shape)
        info = _CACHE_KINDS.get(name)
        if info is None or info[1] == "scalar":
            return P(*([None] * nd))
        trailing, kind = info
        off = nd - trailing  # leading stack dims (scan periods)
        parts: list = [None] * nd
        b_dim = off
        long_dim = nd - 1 if kind == "convd" else off + 1  # convd: channel dim
        # KV caches: sharding the SEQUENCE dim makes the per-token write
        # (dynamic-update-slice at a runtime position) lower as
        # all-gather + update + reslice — the whole cache crosses the wire
        # every step.  Sharding the trailing FEATURE dim (head_dim /
        # kv-lora) keeps the write local; attention then only psums small
        # per-row score partials.  REPRO_CACHE_SHARD=seq restores the
        # baseline for §Perf before/after comparison.
        import os as _os

        # default "seq": with the masked where-write (attention._cache_write)
        # the per-token update stays local; feature-dim sharding measured
        # WORSE (GSPMD all-gathers the contracted feature dim for scores).
        feature_first = (
            kind == "kv" and _os.environ.get("REPRO_CACHE_SHARD", "seq") == "feature"
        )
        batch_axis = rules.resolve("batch")
        model_axis = rules.resolve("tp")
        b_size = rules.axis_size(batch_axis)
        m_size = rules.axis_size(model_axis)
        b_ok = batch_axis is not None and shape[b_dim] % b_size == 0 and shape[b_dim] >= b_size
        if b_ok:
            parts[b_dim] = batch_axis
            feat_dim = nd - 1
            if (
                feature_first
                and model_axis is not None
                and shape[feat_dim] % m_size == 0
                and shape[feat_dim] >= m_size
            ):
                parts[feat_dim] = model_axis
            elif model_axis is not None and shape[long_dim] % m_size == 0 and shape[long_dim] >= m_size:
                parts[long_dim] = model_axis
        else:
            # batch unshardable: spread the long dim over every axis we can
            all_axes = tuple(
                a for a in (batch_axis if isinstance(batch_axis, tuple) else (batch_axis,))
                if a is not None
            ) + tuple(
                a for a in (model_axis if isinstance(model_axis, tuple) else (model_axis,))
                if a is not None
            )
            total = rules.axis_size(all_axes) if all_axes else 1
            if all_axes and shape[long_dim] % total == 0 and shape[long_dim] >= total:
                parts[long_dim] = all_axes
            elif model_axis is not None and shape[long_dim] % m_size == 0:
                parts[long_dim] = model_axis
        return P(*parts)

    return jax.tree_util.tree_map_with_path(leaf_spec, cache)


def constrain_like_params(tree: Any) -> Any:
    """with_sharding_constraint a params-shaped tree (e.g. gradients) to the
    param layout rules.  Telling GSPMD the target sharding at the partial-sum
    source turns full-gradient all-reduces into reduce-scatters (ZeRO-2).
    No-op without an installed mesh."""
    rules = get_mesh()
    if rules is None:
        return tree
    specs = param_specs(tree, rules)
    return jax.tree.map(
        lambda x, s: jax.lax.with_sharding_constraint(
            x, NamedSharding(rules.mesh, s)
        ),
        tree,
        specs,
    )
