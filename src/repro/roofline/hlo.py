"""Parse collective traffic out of compiled/optimized HLO text.

cost_analysis() reports FLOPs and HBM bytes but NOT collective bytes, so we
regex the SPMD module: every ``all-gather`` / ``all-reduce`` /
``reduce-scatter`` / ``all-to-all`` / ``collective-permute`` instruction,
its result shape, and its replica-group size.

Per-device wire-bytes model (ring algorithms):
    all-gather        : result_bytes * (g-1)/g         (receives all but own shard)
    reduce-scatter    : result_bytes * (g-1)           (input = g * result)
    all-reduce        : 2 * result_bytes * (g-1)/g     (RS + AG phases)
    all-to-all        : result_bytes * (g-1)/g
    collective-permute: result_bytes

``collective_bytes`` returns GLOBAL bytes = per-device * num_devices, so the
roofline term collective_bytes / (chips * link_bw) reduces to per-chip wire
bytes over per-chip link bandwidth.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

from repro.roofline.constants import BYTES

# e.g. "  %all-reduce.1 = bf16[16,1024]{1,0} all-reduce(...), replica_groups={{0,1},...}"
_COLLECTIVE_RE = re.compile(
    r"=\s*(?P<type>\([^)]*\)|[a-z0-9]+\[[^\]]*\][^\s]*)\s*"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
)
_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([0-9, ]*)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * BYTES.get(dtype, 4)
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_V2_RE.search(line)
    if m:  # iota form replica_groups=[num_groups,group_size]<...>
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        elems = [e for e in m.group(1).replace(" ", "").split(",") if e]
        return max(len(elems), 1)
    return 1


_FACTORS = {
    "all-gather": lambda g: (g - 1) / g,
    "reduce-scatter": lambda g: float(g - 1),
    "all-reduce": lambda g: 2.0 * (g - 1) / g,
    "all-to-all": lambda g: (g - 1) / g,
    "collective-permute": lambda g: 1.0,
}


@dataclasses.dataclass
class CollectiveStats:
    per_device_bytes: float
    global_bytes: float
    by_op: dict[str, float]  # per-device bytes per op kind
    counts: dict[str, int]

    def dominant(self) -> str:
        return max(self.by_op, key=self.by_op.get) if self.by_op else "none"


def collective_stats(hlo_text: str, num_devices: int) -> CollectiveStats:
    per_dev = 0.0
    by_op: dict[str, float] = defaultdict(float)
    counts: dict[str, int] = defaultdict(int)
    seen_start: set[str] = set()
    for line in hlo_text.splitlines():
        m = _COLLECTIVE_RE.search(line)
        if not m:
            continue
        # -start/-done pairs describe one transfer; count the start only
        if "-done(" in line:
            continue
        op = m.group("op")
        g = _group_size(line)
        if g <= 1 and op != "collective-permute":
            continue  # degenerate group: no wire traffic
        nbytes = _shape_bytes(m.group("type"))
        if op in ("all-gather", "all-to-all"):
            # result tuple may include aliased input buffer; HLO convention
            # here is result == gathered output, fine as-is
            pass
        moved = nbytes * _FACTORS[op](g)
        per_dev += moved
        by_op[op] += moved
        counts[op] += 1
    return CollectiveStats(
        per_device_bytes=per_dev,
        global_bytes=per_dev * num_devices,
        by_op=dict(by_op),
        counts=dict(counts),
    )
