"""Three-term roofline from a compiled dry-run artifact.

    compute term    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory term     = HLO_bytes / (chips * HBM_bw)
    collective term = collective_bytes / (chips * link_bw)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()`` (whole-program,
all devices); collective_bytes comes from parsing the SPMD HLO (see hlo.py).
MODEL_FLOPS = 6*N*D for dense archs (6*N_active*D for MoE) measures how much
of the compiled compute is "useful" — remat recompute, padding and dead work
show up as a low ratio.
"""
from __future__ import annotations

import dataclasses

from repro.roofline import constants
from repro.roofline.hlo import CollectiveStats, collective_stats


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    num_devices: int
    hlo_flops: float
    hlo_bytes: float
    collective: CollectiveStats
    model_flops: float
    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / self.hlo_flops if self.hlo_flops > 0 else 0.0

    @property
    def roofline_fraction(self) -> float:
        """What fraction of the bound-term time is useful model compute —
        the headline score: model_flops_time / achievable_step_time."""
        ideal = self.model_flops / (self.num_devices * constants.PEAK_FLOPS_BF16)
        return ideal / self.bound_s if self.bound_s > 0 else 0.0

    def row(self) -> dict:
        return {
            "arch": self.arch,
            "shape": self.shape,
            "mesh": self.mesh,
            "devices": self.num_devices,
            "hlo_gflops": self.hlo_flops / 1e9,
            "hlo_gbytes": self.hlo_bytes / 1e9,
            "coll_gbytes_global": self.collective.global_bytes / 1e9,
            "compute_ms": self.compute_s * 1e3,
            "memory_ms": self.memory_s * 1e3,
            "collective_ms": self.collective_s * 1e3,
            "dominant": self.dominant,
            "model_gflops": self.model_flops / 1e9,
            "useful_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def stage_step_flops(cfg, stage: int, n_tokens: int) -> float:
    """Forward FLOPs of serving-stage ``stage`` (1-based) over ``n_tokens``
    device tokens: 2 * active_params_h per token — the same accounting as
    ``model_flops_for`` (param-FLOPs dominate; attention-vs-cache reads are
    charged to the byte side)."""
    from repro.core.profiles import stage_param_counts

    params = stage_param_counts(cfg)[stage - 1]
    return 2.0 * params * n_tokens


def stage_step_bytes(
    cfg, stage: int, n_calls: int, n_tokens: int, dtype_bytes: int = 2
) -> float:
    """HBM traffic of ``n_calls`` invocations of stage ``stage``: the weight
    stream (params * dtype_bytes, re-read every call — the decode-side
    floor) plus the activation stream (tokens * d_model in and out)."""
    from repro.core.profiles import stage_param_counts

    params = stage_param_counts(cfg)[stage - 1]
    weights = float(n_calls) * params * dtype_bytes
    activations = 2.0 * n_tokens * cfg.d_model * dtype_bytes
    return weights + activations


def stage_roofline_bound_s(flops: float, nbytes: float) -> float:
    """Single-chip roofline time bound: max of the compute and memory terms."""
    return max(
        flops / constants.PEAK_FLOPS_BF16, nbytes / constants.HBM_BW
    )


def model_flops_for(cfg, shape) -> float:
    """6*N*D (dense) / 6*N_active*D (MoE); decode counts one new token."""
    n = cfg.param_count(active_only=cfg.moe is not None)
    if shape.mode == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens  # forward only
    # decode: one token per sequence; attention reads the cache but
    # param-FLOPs dominate the 6ND-style accounting (2*N per token fwd)
    return 2.0 * n * shape.global_batch


def build_report(
    *,
    arch: str,
    shape_name: str,
    mesh_name: str,
    num_devices: int,
    cost_analysis: dict,
    hlo_text: str,
    model_flops: float,
) -> RooflineReport:
    # cost_analysis() reports the per-device SPMD program; globalize.
    flops = float(cost_analysis.get("flops", 0.0)) * num_devices
    nbytes = float(cost_analysis.get("bytes accessed", 0.0)) * num_devices
    coll = collective_stats(hlo_text, num_devices)
    return RooflineReport(
        arch=arch,
        shape=shape_name,
        mesh=mesh_name,
        num_devices=num_devices,
        hlo_flops=flops,
        hlo_bytes=nbytes,
        collective=coll,
        model_flops=model_flops,
        compute_s=flops / (num_devices * constants.PEAK_FLOPS_BF16),
        memory_s=nbytes / (num_devices * constants.HBM_BW),
        collective_s=coll.global_bytes / (num_devices * constants.ICI_BW),
    )
