"""Analytic cost corrections for loops the unrolled measurement cannot open.

Only one such loop exists in the zoo: sLSTM's per-timestep recurrence
(h_{t-1} feeds the gates — trip count == seq_len, not unrollable).  The
measured cost counts its body once; this module adds the missing
(seq_len - 1) iterations.

Per-step body cost (see ssm.slstm_cell):
  flops : recurrent gate matmul  B * H * P * 4P * 2   (+ O(B*H*P) elementwise)
  bytes : r_gates weights H*P*4P*4  +  state r/w ~ 9*B*H*P*4  +  w_t B*4d*4
Training multiplies flops by ~4 (fwd + remat-recompute-fwd + ~2x bwd).
"""
from __future__ import annotations

from repro.configs.base import ArchConfig, ShapeSpec


def slstm_missing_cost(cfg: ArchConfig, shape: ShapeSpec) -> tuple[float, float]:
    """(extra_flops, extra_bytes) to add to fitted totals; (0, 0) if no sLSTM."""
    if cfg.xlstm is None or "slstm" not in cfg.period:
        return 0.0, 0.0
    if shape.mode == "decode":
        return 0.0, 0.0  # single step: body count is already right
    d = cfg.xlstm.d_model
    H = cfg.xlstm.num_heads
    P = d // H
    B = shape.global_batch
    S = shape.seq_len
    n_slstm = cfg.period.count("slstm") * (cfg.num_layers // len(cfg.period))

    per_step_flops = B * H * P * (4 * P) * 2 + 24.0 * B * H * P
    per_step_bytes = (
        H * P * 4 * P * 4.0  # r_gates re-read
        + 9.0 * B * H * P * 4.0  # carry state read/write
        + B * 4 * d * 4.0  # w_t slice
    )
    steps_missing = S - 1
    flops = per_step_flops * steps_missing * n_slstm
    nbytes = per_step_bytes * steps_missing * n_slstm
    if shape.mode == "train":
        flops *= 4.0  # fwd + remat fwd + ~2x bwd
        nbytes *= 3.0
    return flops, nbytes
