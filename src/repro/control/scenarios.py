"""Composable live-environment perturbations (the paper's Figs. 7–8 regime).

A :class:`Scenario` is a list of timed :class:`ScenarioEvent` mutations the
engine applies to its *physical* serve-time topology as the simulated clock
passes each event — plus optional modulation of the arrival process itself
(piecewise arrival-rate factors and time-varying end-device weights).  The
optimizer's view never sees these mutations directly; it has to notice them
through telemetry and reconfigure, which is exactly what the closed-loop
benchmarks measure.

Builders pick concrete victims from the deployed topology (and, when given,
the live offloading strategy ``p`` — the busiest replica is the one the
strategy actually leans on), so a scenario composed for one network stresses
the load-bearing parts of another.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import topology as topo_lib
from repro.core.types import Topology


@dataclasses.dataclass(frozen=True)
class ScenarioEvent:
    """One timed mutation of the physical environment.

    kind:
      * ``mu_scale``    — scale node ``node``'s compute capacity by ``factor``
      * ``phi_scale``   — scale ``nodes``' external arrival rates by ``factor``
        (bookkeeping: the realized arrival process is shaped by the scenario's
        arrival modulation, this keeps the environment's ground truth aligned)
      * ``rate_scale``  — scale the bandwidth of the ``pairs`` links
      * ``fail``        — fail-stop node ``node`` (engine re-executes resident
        tasks from their source EDs and drops the node from both topologies)
    """

    time: float
    kind: str
    node: int = -1
    nodes: tuple[int, ...] = ()
    pairs: tuple[tuple[int, int], ...] = ()
    factor: float = 1.0


@dataclasses.dataclass
class Scenario:
    name: str
    events: list[ScenarioEvent] = dataclasses.field(default_factory=list)
    # piecewise-constant arrival-rate modulation: sorted (t, factor) steps,
    # factor holding from t onward; empty = homogeneous arrivals
    arrival_steps: tuple[tuple[float, float], ...] = ()
    # time-varying end-device weights: (t0, t1, {node: factor}) windows
    ed_windows: tuple[tuple[float, float, dict], ...] = ()

    # -- arrival-process modulation ----------------------------------------
    @property
    def modulates_arrivals(self) -> bool:
        return any(f != 1.0 for _, f in self.arrival_steps)

    def arrival_factor(self, t: float) -> float:
        f = 1.0
        for t0, step in self.arrival_steps:
            if t >= t0:
                f = step
        return f

    @property
    def max_arrival_factor(self) -> float:
        return max([f for _, f in self.arrival_steps] + [1.0])

    @property
    def modulates_eds(self) -> bool:
        return bool(self.ed_windows)

    def ed_weights(
        self, t: float, eds: np.ndarray, base_w: np.ndarray
    ) -> np.ndarray:
        w = np.asarray(base_w, np.float64).copy()
        for t0, t1, factors in self.ed_windows:
            if t0 <= t < t1:
                for i, v in enumerate(eds):
                    w[i] *= factors.get(int(v), 1.0)
        return w

    # -- environment mutation (engine-side, in place) -----------------------
    def apply_env(self, ev: ScenarioEvent, env: Topology) -> None:
        """Apply one (non-failure) event to the engine's private physical
        topology; arrays are mutated in place so every closure over the
        environment sees the change immediately."""
        if ev.kind == "mu_scale":
            env.mu[ev.node] = env.mu[ev.node] * ev.factor
        elif ev.kind == "phi_scale":
            for v in ev.nodes:
                env.phi_ext[v] = env.phi_ext[v] * ev.factor
        elif ev.kind == "rate_scale":
            env.edge_rate[:] = topo_lib.with_link_degradation(
                env, ev.pairs, ev.factor
            ).edge_rate
        else:
            raise ValueError(f"engine handles kind={ev.kind!r} itself")


# ---------------------------------------------------------------------------
# victim selection
# ---------------------------------------------------------------------------


def busiest_replica(topo: Topology, p: np.ndarray | None, stage: int = 1) -> int:
    """The stage-``stage`` node carrying the most strategy-weighted inbound
    traffic (uniform strategy when ``p`` is None) — the replica whose loss or
    throttling hurts a stale strategy the most."""
    if p is None:
        deg = np.maximum(topo.out_degree(), 1)
        p = 1.0 / deg[topo.edge_src]
    p = np.asarray(p, np.float64)
    mass = np.zeros(topo.num_nodes)
    src_stage = topo.node_stage[topo.edge_src]
    # weight stage-0 sources by their external rate; deeper sources equally
    w_src = np.where(topo.phi_ext > 0, topo.phi_ext, 1.0)
    for e in range(topo.num_edges):
        if int(topo.node_stage[topo.edge_dst[e]]) == stage:
            mass[topo.edge_dst[e]] += p[e] * w_src[topo.edge_src[e]]
    del src_stage
    nodes = topo.nodes_at_stage(stage)
    return int(nodes[int(np.argmax(mass[nodes]))])


def _safe_failure_victims(topo: Topology, stage: int = 1) -> list[int]:
    """Stage nodes whose removal strands no offloader (checked by actually
    trying the structural mutation)."""
    out = []
    for v in topo.nodes_at_stage(stage):
        try:
            topo_lib.with_node_failure(topo, int(v))
        except RuntimeError:
            continue
        out.append(int(v))
    return out


# ---------------------------------------------------------------------------
# builders
# ---------------------------------------------------------------------------


def arrival_burst(
    topo: Topology,
    t0: float,
    t1: float,
    factor: float = 4.0,
    p: np.ndarray | None = None,
    ed_share: float = 0.5,
    seed: int = 0,
) -> Scenario:
    """A subset of end devices (``ed_share`` of the external-rate mass)
    bursts to ``factor``x during [t0, t1): the total arrival rate rises AND
    the traffic mix skews toward the bursting devices' preferred replicas —
    the re-balancing case a uniform burst would hide."""
    del p
    rng = np.random.default_rng(seed)
    eds = topo.nodes_at_stage(0)
    order = rng.permutation(len(eds))
    w = topo.phi_ext[eds]
    total = max(float(w.sum()), 1e-12)
    chosen: list[int] = []
    acc = 0.0
    for i in order:
        chosen.append(int(eds[i]))
        acc += float(w[i])
        if acc / total >= ed_share:
            break
    share = acc / total
    # bursting share at factor-x lifts the TOTAL rate by 1 + share*(factor-1)
    total_factor = 1.0 + share * (factor - 1.0)
    return Scenario(
        name="burst",
        events=[
            ScenarioEvent(t0, "phi_scale", nodes=tuple(chosen), factor=factor),
            ScenarioEvent(t1, "phi_scale", nodes=tuple(chosen), factor=1.0 / factor),
        ],
        arrival_steps=((0.0, 1.0), (t0, total_factor), (t1, 1.0)),
        ed_windows=((t0, t1, {v: factor for v in chosen}),),
    )


def node_slowdown(
    topo: Topology,
    t0: float,
    t1: float,
    factor: float = 0.15,
    p: np.ndarray | None = None,
    node: int | None = None,
) -> Scenario:
    """The busiest stage-1 replica throttles to ``factor`` of nameplate at
    ``t0`` (thermal / co-tenant interference) and recovers at ``t1``."""
    victim = busiest_replica(topo, p) if node is None else int(node)
    return Scenario(
        name="slowdown",
        events=[
            ScenarioEvent(t0, "mu_scale", node=victim, factor=factor),
            ScenarioEvent(t1, "mu_scale", node=victim, factor=1.0 / factor),
        ],
    )


def link_degradation(
    topo: Topology,
    t0: float,
    t1: float,
    factor: float = 0.1,
    p: np.ndarray | None = None,
    node: int | None = None,
) -> Scenario:
    """Every link INTO the busiest stage-1 replica degrades to ``factor`` of
    its bandwidth during [t0, t1) (congested uplink)."""
    victim = busiest_replica(topo, p) if node is None else int(node)
    pairs = tuple(
        (int(s), int(d))
        for s, d in zip(topo.edge_src, topo.edge_dst)
        if int(d) == victim
    )
    return Scenario(
        name="link",
        events=[
            ScenarioEvent(t0, "rate_scale", pairs=pairs, factor=factor),
            ScenarioEvent(t1, "rate_scale", pairs=pairs, factor=1.0 / factor),
        ],
    )


def node_failure(
    topo: Topology,
    t0: float,
    p: np.ndarray | None = None,
    node: int | None = None,
) -> Scenario:
    """Fail-stop of (by default) the busiest SAFE stage-1 replica at ``t0``
    — resident tasks re-execute from their EDs, the strategy renormalizes,
    and the controller re-balances the survivors."""
    if node is None:
        safe = _safe_failure_victims(topo)
        if not safe:
            raise RuntimeError(
                "no stage-1 replica can fail without stranding an offloader; "
                "use elastic_remesh first"
            )
        busy = busiest_replica(topo, p)
        node = busy if busy in safe else safe[0]
    return Scenario(
        name="failure", events=[ScenarioEvent(t0, "fail", node=int(node))]
    )


NAMES = ("burst", "slowdown", "link", "failure")


def get_scenario(
    name: str,
    topo: Topology,
    p: np.ndarray | None = None,
    horizon: float = 5.0,
    seed: int = 0,
    **kw,
) -> Scenario:
    """Build a named scenario with its disruption window anchored to
    ``horizon``.  Mode changes persist per slot exactly as the paper's
    dynamic regime re-randomizes them: the slowdown's computing mode holds
    through the measured window (recovery lands at 2x horizon) and the
    failure at 0.25 is permanent; the burst spans [0.2, 0.9) and the link
    degradation [0.25, 0.7)."""
    t0, t1 = 0.25 * horizon, 0.7 * horizon
    if name == "burst":
        return arrival_burst(topo, 0.2 * horizon, 0.9 * horizon, p=p, seed=seed, **kw)
    if name == "slowdown":
        return node_slowdown(topo, 0.2 * horizon, 2.0 * horizon, p=p, **kw)
    if name == "link":
        return link_degradation(topo, t0, t1, p=p, **kw)
    if name == "failure":
        return node_failure(topo, t0, p=p, **kw)
    raise ValueError(f"unknown scenario {name!r}; choose from {NAMES}")
