"""Online control plane: closed-loop DTO-EE over the live serving engine.

``telemetry``   — sliding-window estimators fed by the engine's streaming
                  hooks (arrivals, batch service times, transfers, exits).
``controller``  — slot-boundary reconfiguration: effective topology from
                  telemetry -> warm-started DTO-EE phase -> atomic install
                  after the decision time, with hysteresis.
``scenarios``   — composable live-environment perturbations (bursts,
                  slowdowns, link degradation, node failure) driving the
                  paper's Figs. 7–8 dynamic regime against the real engine.
"""
from repro.control.controller import (
    LOCAL_COMM_S,
    ControllerConfig,
    ReconfigController,
    ReconfigPlan,
)
from repro.control.scenarios import (
    NAMES as SCENARIO_NAMES,
    Scenario,
    ScenarioEvent,
    arrival_burst,
    busiest_replica,
    get_scenario,
    link_degradation,
    node_failure,
    node_slowdown,
)
from repro.control.telemetry import Telemetry, TelemetryConfig

__all__ = [
    "LOCAL_COMM_S", "ControllerConfig", "ReconfigController", "ReconfigPlan",
    "SCENARIO_NAMES", "Scenario", "ScenarioEvent", "arrival_burst",
    "busiest_replica", "get_scenario", "link_degradation", "node_failure",
    "node_slowdown", "Telemetry", "TelemetryConfig",
]
