"""Streaming telemetry for the online control plane.

The serving engine emits one observation per event as its simulated clock
advances — arrivals at end devices, stage batches (GFLOPs, wall seconds,
queue depth), residual-stream transfers, and exit decisions.
:class:`Telemetry` subscribes to the engine's instrumentation stream
(:mod:`repro.obs.stream` — the same call sites that feed span tracing and
metrics), consuming the hook subset it defines and folding the
observations into sliding-window / EWMA estimators; it can render
them as an *effective* :class:`~repro.core.types.Topology`: the optimizer's
static profile with every measured quantity replaced by its live estimate.
That effective topology is what the controller re-optimizes against — the
measure half of the measure→re-optimize loop (EdgeShard / MoE² style) the
paper's dynamic experiments assume.

Estimator choices:

  * per-node service rates ``mu`` ride on :class:`StragglerMonitor` (EWMA of
    GFLOPs/wall per batch) — capacity drift shows up within a few batches;
  * per-ED arrival rates are sliding-window counts (bursts need a windowed
    rate, an EWMA over inter-arrival gaps reacts too slowly at low rates);
  * link rates are EWMAs keyed by the ``(src, dst)`` pair, so estimates
    survive edge-index shifts when a node failure rewrites the edge arrays;
  * queue depths and the realized exit-stage histogram are kept for
    reporting / prediction priors, not for the optimizer (DTO-EE's queueing
    model derives depths itself).
"""
from __future__ import annotations

import dataclasses
import heapq

import numpy as np

from repro.core.types import Topology
from repro.runtime.elastic import StragglerMonitor


@dataclasses.dataclass(frozen=True)
class TelemetryConfig:
    window_s: float = 2.0  # sliding window for arrival / exit counts
    ewma_alpha: float = 0.3  # EWMA weight for service + link rates
    mu_floor: float = 1e-6  # effective-topology clamp (validate() needs > 0)


class Telemetry:
    """Sliding-window estimators over the engine's streaming observations.

    All hooks take the *simulated* timestamp of the observation; estimators
    take ``now`` so the window can be evicted lazily.  The object is cheap
    enough to leave attached to every serve call.
    """

    def __init__(self, topo: Topology, config: TelemetryConfig | None = None):
        self.config = config or TelemetryConfig()
        self.num_nodes = topo.num_nodes
        self.num_stages = topo.num_stages
        self.monitor = StragglerMonitor.from_topology(
            topo, alpha=self.config.ewma_alpha
        )
        n = self.num_nodes
        self._t0: float | None = None  # earliest observation timestamp
        # sliding windows: min-heaps of (t, key) + count arrays kept in sync.
        # Heaps, not FIFO deques: observations arrive out of timestamp order
        # (batches are stamped at completion when scheduled, arrivals carry
        # their ED timestamp but land at first-hop completion), and eviction
        # must still drop exactly the entries older than the window.
        self._arr_q: list[tuple[float, int]] = []
        self._arr_count = np.zeros(n, np.int64)
        self._arr_seen = False  # any arrival ever: empty window then means ~0
        self._srv_q: list[tuple[float, int]] = []
        self._srv_count = np.zeros(n, np.int64)
        self._exit_q: list[tuple[float, int]] = []
        self._exit_count = np.zeros(self.num_stages + 1, np.int64)
        # EWMAs
        self._edge_hat: dict[tuple[int, int], float] = {}
        self._qdepth_hat = np.zeros(n, np.float64)
        self._dead: set[int] = set()

    def attach_monitor(self, monitor: StragglerMonitor) -> None:
        """Adopt the engine's StragglerMonitor so there is ONE capacity EWMA:
        the estimates the controller plans from are exactly the ones
        ``ServeStats.capacity_estimates`` reports (the engine calls this at
        serve start)."""
        self.monitor = monitor

    # -- hooks (instrumentation-stream subscriber subset) --------------------
    def _seen(self, t: float) -> None:
        if self._t0 is None or t < self._t0:
            self._t0 = t

    def on_arrival(self, t: float, node: int, rid: int = -1) -> None:
        self._seen(t)
        self._arr_seen = True
        heapq.heappush(self._arr_q, (t, int(node)))
        self._arr_count[int(node)] += 1

    def on_batch(
        self, t: float, node: int, gflops: float, wall: float,
        queue_depth: int, **_,
    ) -> None:
        self._seen(t)
        node = int(node)
        self.monitor.observe(node, gflops, wall)
        heapq.heappush(self._srv_q, (t, node))
        self._srv_count[node] += 1
        a = self.config.ewma_alpha
        self._qdepth_hat[node] = (1 - a) * self._qdepth_hat[node] + a * queue_depth

    def on_transfer(
        self, t0: float, t1: float, wall: float, src: int, dst: int,
        rid: int = -1, mb: float = 0.0,
    ) -> None:
        # ``wall`` is the modeled hop time (mb / edge_rate), passed explicitly
        # rather than recomputed as t1 - t0: the estimator must see the exact
        # float the engine charged, not its round-trip through the timeline
        if wall <= 0:
            return
        self._seen(t1)
        key = (int(src), int(dst))
        rate = mb / wall
        prev = self._edge_hat.get(key)
        a = self.config.ewma_alpha
        self._edge_hat[key] = rate if prev is None else (1 - a) * prev + a * rate

    def on_exit(self, t: float, rid: int, stage: int, conf: float = 0.0) -> None:
        self._seen(t)
        heapq.heappush(self._exit_q, (t, int(stage)))
        self._exit_count[int(stage)] += 1

    def on_failure(self, t: float, node: int) -> None:
        """Failure detection: pin the dead replica's capacity estimate."""
        self._dead.add(int(node))
        self.monitor.mu_hat[int(node)] = self.config.mu_floor

    # -- estimators ---------------------------------------------------------
    def _evict(self, now: float) -> None:
        cut = now - self.config.window_s
        while self._arr_q and self._arr_q[0][0] < cut:
            _, v = heapq.heappop(self._arr_q)
            self._arr_count[v] -= 1
        while self._srv_q and self._srv_q[0][0] < cut:
            _, v = heapq.heappop(self._srv_q)
            self._srv_count[v] -= 1
        while self._exit_q and self._exit_q[0][0] < cut:
            _, s = heapq.heappop(self._exit_q)
            self._exit_count[s] -= 1

    def _span(self, now: float) -> float:
        if self._t0 is None:
            return 0.0
        return min(self.config.window_s, max(now - self._t0, 0.0))

    def arrival_rates(self, view: Topology, now: float) -> np.ndarray:
        """Measured per-node external arrival rates; the view's values where
        nothing has been observed yet (cold start)."""
        self._evict(now)
        phi = view.phi_ext.copy()
        span = self._span(now)
        if span > 0 and self._arr_seen:
            eds = np.nonzero(view.node_stage == 0)[0]
            phi[eds] = self._arr_count[eds] / span
        return phi

    def mu_estimates(self, view: Topology, now: float) -> np.ndarray:
        """EWMA capacity estimates for replicas with recent batches; the
        view's values elsewhere."""
        self._evict(now)
        mu = view.mu.copy()
        seen = np.nonzero(self._srv_count > 0)[0]
        for v in seen:
            mu[v] = max(float(self.monitor.mu_hat[v]), self.config.mu_floor)
        for v in self._dead:
            mu[v] = self.config.mu_floor
        return mu

    def edge_rate_estimates(self, view: Topology) -> np.ndarray:
        rate = view.edge_rate.copy()
        for i, (s, d) in enumerate(zip(view.edge_src, view.edge_dst)):
            hat = self._edge_hat.get((int(s), int(d)))
            if hat is not None:
                rate[i] = max(hat, 1e-9)
        return rate

    def exit_fractions(self, now: float) -> np.ndarray:
        """Realized exit-stage distribution over the window (index = stage;
        0 unused)."""
        self._evict(now)
        total = self._exit_count.sum()
        if total == 0:
            return np.zeros_like(self._exit_count, np.float64)
        return self._exit_count / total

    def queue_depths(self) -> np.ndarray:
        return self._qdepth_hat.copy()

    def effective_topology(self, view: Topology, now: float) -> Topology:
        """The view with every measured quantity replaced by its estimate —
        what the controller's configuration phase optimizes against."""
        return dataclasses.replace(
            view,
            mu=self.mu_estimates(view, now),
            phi_ext=self.arrival_rates(view, now),
            edge_rate=self.edge_rate_estimates(view),
        )

    def snapshot(self, view: Topology, now: float) -> dict:
        """Loggable summary of the current estimates."""
        self._evict(now)
        mu = self.mu_estimates(view, now)
        es = np.nonzero(view.node_stage > 0)[0]
        return {
            "t": float(now),
            "arrival_rate_total": float(
                self.arrival_rates(view, now)[view.node_stage == 0].sum()
            ),
            "mu_estimates": {int(v): float(mu[v]) for v in es},
            "mean_queue_depth": float(self._qdepth_hat[es].mean()) if es.size else 0.0,
            "exit_fractions": self.exit_fractions(now).tolist(),
            "observed_edges": len(self._edge_hat),
        }
