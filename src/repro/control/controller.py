"""Closed-loop reconfiguration controller for the serving engine.

At every slot boundary (``interval`` simulated seconds) the controller:

  1. renders the telemetry's **effective topology** — the optimizer's view
     with measured capacities / arrival rates / link rates substituted in;
  2. applies **hysteresis**: if the measured environment drifted less than
     ``drift_deadband`` (relative) since the last accepted plan, nothing
     happens — re-optimizing a quiet environment only thrashes routing;
  3. **warm-starts** a DTO-EE configuration phase (Algorithm 3) from the
     engine's live state against the effective topology, off to the side —
     the serving data plane keeps routing on the live ``p``/thresholds;
  4. returns a :class:`ReconfigPlan` carrying the phase result plus its
     **decision time** (``rounds x local_comm_s``, the paper's §4.1 cost of
     a distributed configuration phase).  The engine installs the plan only
     after that much simulated time has passed, so slow reconfigurations
     route on stale strategies exactly as the paper charges them.

``install`` swaps topology view, round program, offloading strategy and
thresholds into the engine atomically (between batches — the engine applies
it at an event boundary), and rejects plans whose edge structure was
invalidated by a node failure that landed mid-decision.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from repro.core import dto_ee
from repro.core.types import Topology

from repro.control.telemetry import Telemetry

#: paper §4.1: one local RUR/RUS exchange costs ~2 ms
LOCAL_COMM_S = 0.002


@dataclasses.dataclass(frozen=True)
class ControllerConfig:
    interval: float = 1.0  # simulated seconds between reconfiguration ticks
    rounds: int = 30  # DTO-EE rounds per mid-serve configuration phase
    local_comm_s: float = LOCAL_COMM_S
    adapt_thresholds: bool = True
    warm_start: bool = True  # False: re-solve each tick from a cold state
    # hysteresis: skip planning entirely below this relative environment
    # drift, and skip the install when the new strategy barely moved
    drift_deadband: float = 0.05
    p_deadband: float = 1e-3

    @property
    def decision_time(self) -> float:
        return self.rounds * self.local_comm_s


@dataclasses.dataclass
class ReconfigPlan:
    """A planned (not yet installed) configuration update."""

    state: dto_ee.DtoState
    topo: Topology  # effective topology the phase optimized against
    round_step: Callable
    decision_time: float
    t_planned: float
    p_l1: float  # mean |p_new - p_live| at plan time
    drift: float  # relative environment drift that triggered the plan


def _rel_drift(ref: Topology, eff: Topology) -> float:
    """Max relative change of any measured quantity between two same-shaped
    topologies (the hysteresis trigger)."""
    es = ref.node_stage > 0
    mu_ref = np.maximum(ref.mu[es], 1e-12)
    d_mu = float(np.max(np.abs(eff.mu[es] - ref.mu[es]) / mu_ref)) if es.any() else 0.0
    phi_ref = max(float(ref.phi_ext.sum()), 1e-12)
    d_phi = abs(float(eff.phi_ext.sum()) - float(ref.phi_ext.sum())) / phi_ref
    rate_ref = np.maximum(ref.edge_rate, 1e-12)
    d_rate = float(np.max(np.abs(eff.edge_rate - ref.edge_rate) / rate_ref))
    return max(d_mu, d_phi, d_rate)


class ReconfigController:
    """Drives closed-loop DTO-EE over a live ``CollaborativeEngine.serve``.

    Pass it (with its telemetry) to ``serve(controller=...)``; the engine
    calls :meth:`plan` at tick events and :meth:`install` once the plan's
    decision time has elapsed.
    """

    def __init__(self, telemetry: Telemetry, config: ControllerConfig | None = None):
        self.telemetry = telemetry
        self.config = config or ControllerConfig()
        if self.config.interval <= 0:
            raise ValueError("controller interval must be positive")
        self._ref_topo: Topology | None = None  # environment at last accept
        self.log: list[dict] = []

    @property
    def interval(self) -> float:
        return self.config.interval

    def plan(self, engine, now: float) -> ReconfigPlan | None:
        cfg = self.config
        view = engine.topo
        eff = self.telemetry.effective_topology(view, now)
        ref = self._ref_topo if self._ref_topo is not None else view
        if ref.num_edges != eff.num_edges:
            ref = view  # a failure rewrote the structure since the last plan
        drift = _rel_drift(ref, eff)
        if drift < cfg.drift_deadband:
            self.log.append(
                {"t": float(now), "action": "skip", "drift": drift}
            )
            return None
        hyper = dataclasses.replace(engine.hyper, rounds=cfg.rounds)
        round_step = dto_ee.make_round_step(eff, engine.profile, hyper)
        state0 = dto_ee.clone_state(engine.state) if cfg.warm_start else None
        res = dto_ee.run_configuration_phase(
            eff,
            engine.profile,
            engine.exit_profile,
            hyper,
            state=state0,
            adapt_thresholds=cfg.adapt_thresholds,
            round_step=round_step,
        )
        p_new = np.asarray(res.state.carry.p, np.float64)
        p_l1 = float(np.mean(np.abs(p_new - engine.p)))
        thr_moved = not np.array_equal(res.state.thresholds, engine.state.thresholds)
        if p_l1 < cfg.p_deadband and not thr_moved:
            # the environment drifted but the optimum barely moved: installing
            # would only churn the routing CDF
            self.log.append(
                {"t": float(now), "action": "hold", "drift": drift, "p_l1": p_l1}
            )
            self._ref_topo = eff
            return None
        self.log.append(
            {
                "t": float(now),
                "action": "plan",
                "drift": drift,
                "p_l1": p_l1,
                "thresholds_moved": thr_moved,
                "decision_time": cfg.decision_time,
            }
        )
        return ReconfigPlan(
            state=res.state,
            topo=eff,
            round_step=round_step,
            decision_time=cfg.decision_time,
            t_planned=float(now),
            p_l1=p_l1,
            drift=drift,
        )

    def install(self, engine, plan: ReconfigPlan) -> bool:
        """Atomically swap the plan into the engine; False if a structure
        change (node failure) landed between plan and install."""
        if plan.topo.num_edges != engine.topo.num_edges:
            self.log.append(
                {"t": plan.t_planned, "action": "stale", "reason": "edge set changed"}
            )
            return False
        engine.topo = plan.topo
        engine.state = plan.state
        engine._round_step = plan.round_step
        self._ref_topo = plan.topo
        self.log.append(
            {
                "t": plan.t_planned + plan.decision_time,
                "action": "install",
                "p_l1": plan.p_l1,
            }
        )
        return True
