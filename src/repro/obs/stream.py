"""The instrumentation stream: one set of engine call sites, N consumers.

Before this module, every observer (telemetry, and anything after it) needed
its own hooks threaded through the engine's hot paths.  Now the engine emits
each event ONCE to an :class:`InstrumentationStream`, which fans it out to
whatever subscribed — the control plane's :class:`~repro.control.telemetry.
Telemetry`, a :class:`~repro.obs.trace.SpanTracer`, a
:class:`~repro.obs.metrics.MetricsCollector` — each consuming the subset of
hooks it defines.

Dispatch cost is kept off the hot path:

  * no subscribers  -> the engine holds ``stream = None`` and skips the
    emission entirely (the disabled path is bitwise identical to an
    uninstrumented build);
  * one subscriber defining a hook -> the stream binds that method directly
    (zero fan-out indirection — the common telemetry-only serve pays exactly
    one bound-method call per event, as before the refactor);
  * several -> a tuple loop.

Hook vocabulary (all timestamps are simulated seconds):

  on_submit(t, rid, ed, arrival)      first hop submitted at the source ED
  on_arrival(t, node, rid)            first-hop transfer completed (legacy
                                      arrival-rate estimator semantics)
  on_transfer(t0, t1, wall, src, dst, rid, mb)   residual-stream hop
  on_loopback(t0, t1, src, dst, rid, mb)         stage-H -> stage-1 token hop
  on_enqueue(t, rid, node)            joined a replica's queue
  on_batch(done, node, gflops, wall, queue_depth, **detail)
                                      one stage batch; detail carries stage,
                                      rids, t_dispatch, t_start, n_rows,
                                      n_tokens, is_decode, wall_clock_s
  on_pool(t, node, used_fraction, hit_blocks, total_blocks)  paged pool sample
  on_exit(t, rid, stage, conf)        retirement
  on_resubmit(t, rid)                 fail-stop re-execution restart
  on_failure(t, node)                 replica fail-stop

A subscriber implements any subset; extra positional/keyword detail it does
not care about must be absorbed (``**_``) so the vocabulary can grow without
touching every consumer.
"""
from __future__ import annotations

from typing import Any

__all__ = ["HOOKS", "InstrumentationStream", "build_stream"]

HOOKS = (
    "on_submit",
    "on_arrival",
    "on_transfer",
    "on_loopback",
    "on_enqueue",
    "on_batch",
    "on_pool",
    "on_exit",
    "on_resubmit",
    "on_failure",
)


def _noop(*args: Any, **kwargs: Any) -> None:
    return None


def _fanout(fns: tuple):
    def dispatch(*args: Any, **kwargs: Any) -> None:
        for f in fns:
            f(*args, **kwargs)

    return dispatch


class InstrumentationStream:
    """Fans each hook out to the subscribers that define it."""

    def __init__(self, subscribers):
        self.subscribers = tuple(s for s in subscribers if s is not None)
        #: any subscriber wants REAL wall-clock timings of stage programs
        #: (the engine only pays the perf_counter reads when this is set)
        self.wants_wall = any(
            getattr(s, "wants_wall_clock", False) for s in self.subscribers
        )
        for name in HOOKS:
            fns = tuple(
                getattr(s, name)
                for s in self.subscribers
                if callable(getattr(s, name, None))
            )
            if not fns:
                setattr(self, name, _noop)
            elif len(fns) == 1:
                setattr(self, name, fns[0])
            else:
                setattr(self, name, _fanout(fns))


def build_stream(*subscribers) -> InstrumentationStream | None:
    """A stream over the non-None subscribers, or None when there are none
    (the engine then skips every emission — the zero-cost disabled path)."""
    subs = [s for s in subscribers if s is not None]
    return InstrumentationStream(subs) if subs else None
