"""Roofline join: measured stage-program wall time vs the hardware bound.

The tracer accumulates REAL ``perf_counter`` wall seconds around every
jitted stage-program call the engine makes (``wants_wall_clock``), keyed by
``(stage, phase)`` and carrying the device work actually shipped (padded
rows, device tokens, call count).  This module joins that with the analytic
per-stage FLOP/byte counts in :mod:`repro.roofline.analysis` to report, per
stage and phase, how far measured compute sits from the roofline bound —
turning the ROADMAP's "as fast as the hardware allows" into a measured gap.

Utilization > 1 is possible and meaningful on this host: the bound assumes
the TPU-class constants in ``roofline/constants.py`` while tests run on CPU,
and tiny stage programs are launch-latency-bound — the *relative* trend
across stages/phases is the signal, and the numbers become absolute on the
target part.
"""
from __future__ import annotations

from repro.roofline.analysis import (
    stage_roofline_bound_s,
    stage_step_bytes,
    stage_step_flops,
)

__all__ = ["roofline_utilization"]


def roofline_utilization(tracer, cfg) -> dict:
    """Measured-vs-roofline utilization per (stage, phase) of one serve.

    Returns ``{"stage{h}.{phase}": {...}}`` rows with the measured wall
    time, the analytic FLOP/byte totals for the device work shipped, the
    roofline bound, and ``utilization = bound_s / measured_s``.
    """
    out: dict[str, dict] = {}
    for (stage, phase), cw in sorted(tracer.compute_wall.items()):
        flops = stage_step_flops(cfg, stage, cw.tokens)
        nbytes = stage_step_bytes(cfg, stage, cw.calls, cw.tokens)
        bound_s = stage_roofline_bound_s(flops, nbytes)
        row = {
            "stage": stage,
            "phase": phase,
            "calls": cw.calls,
            "device_rows": cw.rows,
            "live_rows": cw.live_rows,
            "device_tokens": cw.tokens,
            "modeled_gflops": cw.gflops,
            "analytic_gflops": flops / 1e9,
            "analytic_gbytes": nbytes / 1e9,
            "bound_s": bound_s,
            "measured_wall_s": cw.wall_s,
            "utilization": bound_s / cw.wall_s if cw.wall_s > 0 else 0.0,
            "padded_row_frac": 1.0 - cw.live_rows / cw.rows if cw.rows else 0.0,
        }
        out[f"stage{stage}.{phase}"] = row
    return out
