"""Per-request span tracing for the serving engine (and simulator).

The engine's instrumentation stream (:mod:`repro.obs.stream`) emits one
observation per event as its simulated clock advances.  :class:`SpanTracer`
folds those observations into one **span tree per request**: a contiguous
tiling of the interval ``[arrival, retirement]`` by typed spans —

  ``admission``   arrival at the ED until the first hop is submitted
  ``transfer``    a residual-stream / token hop between two nodes
  ``queue``       waiting in a replica's batcher (includes slot / block
                  admission blocking; ``lost=True`` marks time at a replica
                  that failed before serving the request)
  ``batch_wait``  popped into a batch, waiting for the replica to free
  ``compute``     the stage forward of the batch the request rode in

plus zero-duration *instants* (exit-head decisions, retirements, failures,
re-executions) and counter samples (queue depth, block-pool occupancy).

Because every span is delimited by the same event timestamps that delimit
its neighbours, the tiling is exact: span ``k`` ends on the very float where
span ``k+1`` begins, the first span begins at ``Request.arrival`` and the
last ends at ``Request.t_done`` — so the per-request component sums
reconcile with the reported delay (asserted in tests and by
:func:`repro.obs.attribution.decompose`).

Hot-path cost: each hook appends ONE compact event tuple; span trees,
instants, counters, and the roofline accumulators are materialized lazily by
replaying the event log on first view access (views are read after the
serve, so the serve itself pays only the appends — the <3% overhead budget
the serving benchmark's tracing A/B enforces).

Timestamps are **simulated** seconds; the tracer has no clock of its own —
callers inject event times explicitly (:class:`SimClock` tracks the latest
one for exporters).  Wall-clock durations of the real jitted stage programs
ride along separately (``wants_wall_clock``) and feed the roofline join in
:mod:`repro.obs.roofline_hook`.

When tracing is off the engine skips every emission (``stream is None``), so
the disabled path is bitwise identical to an untraced build; :class:`NullTracer`
is the explicit no-op stub for call sites that want an unconditional object.
"""
from __future__ import annotations

import dataclasses
from typing import Any

__all__ = ["Span", "SpanTracer", "NullTracer", "SimClock", "SPAN_KINDS"]

#: the component vocabulary of the per-request tiling
SPAN_KINDS = ("admission", "transfer", "queue", "batch_wait", "compute")


@dataclasses.dataclass(slots=True)
class Span:
    rid: int
    kind: str
    t0: float
    t1: float
    node: int = -1
    stage: int = -1
    attrs: dict | None = None

    @property
    def duration(self) -> float:
        return self.t1 - self.t0


@dataclasses.dataclass
class SimClock:
    """Injectable simulated-time clock: event sources set ``now`` as their
    heap advances, exporters read the high-water mark."""

    now: float = 0.0

    def advance(self, t: float) -> None:
        if t > self.now:
            self.now = t


@dataclasses.dataclass
class _ComputeWall:
    """Accumulated REAL wall-clock of one (stage, phase) program across a
    serve — the measured half of the roofline join."""

    wall_s: float = 0.0
    calls: int = 0
    rows: int = 0  # padded device rows (machine work)
    live_rows: int = 0
    tokens: int = 0  # padded rows x pass seq length (device tokens)
    gflops: float = 0.0  # modeled GFLOPs charged by the sim clock


class _Materialized:
    """Span trees etc. rebuilt from the event log by :meth:`SpanTracer._replay`."""

    __slots__ = (
        "spans", "instants", "counters", "compute_wall", "arrival", "done",
        "attempts", "batches", "cursor", "queue_start",
    )

    def __init__(self):
        self.spans: dict[int, list[Span]] = {}
        self.instants: list[dict] = []
        self.counters: list[tuple[float, str, int, float]] = []
        self.compute_wall: dict[tuple[int, str], _ComputeWall] = {}
        self.arrival: dict[int, float] = {}
        self.done: dict[int, float] = {}
        self.attempts: dict[int, int] = {}
        # (t_start, t_done, node, stage, live, rows, is_decode) per batch —
        # the per-node busy track of the exported trace
        self.batches: list[tuple] = []
        self.cursor: dict[int, float] = {}
        self.queue_start: dict[int, tuple[float, int]] = {}

    def add_span(
        self, rid: int, kind: str, t0: float, t1: float,
        node: int = -1, stage: int = -1, attrs: dict | None = None,
    ) -> None:
        self.spans.setdefault(rid, []).append(
            Span(rid, kind, t0, t1, node, stage, attrs)
        )


class SpanTracer:
    """Subscriber of the engine's instrumentation stream building span trees.

    Also usable directly (:meth:`add_span` / :meth:`add_instant`) by event
    sources that do their own bookkeeping, e.g. the discrete-event
    simulator.  Every hook is one tuple append; the views below replay the
    log on demand.
    """

    wants_wall_clock = True  # ask the engine to time its stage programs

    def __init__(self):
        self.clock = SimClock()
        self._events: list[tuple] = []
        self._mat: _Materialized | None = None
        self._n_mat = -1

    # -- generic span API (simulator & tests) -------------------------------
    def add_span(
        self, rid: int, kind: str, t0: float, t1: float,
        node: int = -1, stage: int = -1, **attrs,
    ) -> None:
        self._events.append(("span", rid, kind, t0, t1, node, stage,
                             attrs or None))

    def add_instant(
        self, t: float, kind: str, rid: int = -1, node: int = -1,
        stage: int = -1, **attrs,
    ) -> None:
        self._events.append(("inst", t, kind, rid, node, stage, attrs))

    def add_counter(self, t: float, name: str, node: int, value: float) -> None:
        self._events.append(("ctr", t, name, node, value))

    # -- stream hooks (called by the engine via InstrumentationStream) ------
    def on_submit(self, t: float, rid: int, ed: int, arrival: float) -> None:
        self._events.append(("submit", t, rid, ed, arrival))

    def on_resubmit(self, t: float, rid: int) -> None:
        self._events.append(("resubmit", t, rid))

    def on_transfer(
        self, t0: float, t1: float, wall: float, src: int, dst: int,
        rid: int, mb: float,
    ) -> None:
        self._events.append(("transfer", t0, t1, src, dst, rid, mb, False))

    def on_loopback(
        self, t0: float, t1: float, src: int, dst: int, rid: int, mb: float
    ) -> None:
        # stage-H -> stage-1 token loopback of an autoregressive request
        # (not a Telemetry link observation — the modeled time is per-token)
        self._events.append(("transfer", t0, t1, src, dst, rid, mb, True))

    def on_enqueue(self, t: float, rid: int, node: int) -> None:
        self._events.append(("enq", t, rid, node))

    def on_batch(
        self,
        t: float,
        node: int,
        gflops: float,
        wall: float,
        queue_depth: int,
        *,
        stage: int = -1,
        rids: tuple = (),
        t_dispatch: float = 0.0,
        t_start: float = 0.0,
        n_rows: int = 0,
        n_tokens: int = 0,
        is_decode: bool = False,
        wall_clock_s: float = 0.0,
        **_: Any,
    ) -> None:
        self._events.append((
            "batch", t, node, gflops, queue_depth, stage, rids, t_dispatch,
            t_start, n_rows, n_tokens, is_decode, wall_clock_s,
        ))

    def on_pool(
        self, t: float, node: int, used_fraction: float,
        hit_blocks: int = 0, total_blocks: int = 0,
    ) -> None:
        self._events.append(("ctr", t, "pool_occupancy", node, used_fraction))

    def on_exit(self, t: float, rid: int, stage: int, conf: float) -> None:
        self._events.append(("exit", t, rid, stage, conf))

    def on_failure(self, t: float, node: int) -> None:
        self._events.append(("fail", t, node))

    # -- replay -------------------------------------------------------------
    def _replay(self) -> _Materialized:
        """(Re)build span trees from the event log; cached until it grows."""
        if self._mat is not None and self._n_mat == len(self._events):
            return self._mat
        m = _Materialized()
        clock = self.clock
        for ev in self._events:
            op = ev[0]
            if op == "transfer":
                _, t0, t1, src, dst, rid, mb, loop = ev
                attrs = {"src": src, "mb": mb}
                if loop:
                    attrs["loopback"] = True
                m.add_span(rid, "transfer", t0, t1, dst, -1, attrs)
                m.cursor[rid] = t1
                clock.advance(t1)
            elif op == "enq":
                _, t, rid, node = ev
                m.queue_start[rid] = (t, node)
            elif op == "batch":
                (_, t, node, gflops, queue_depth, stage, rids, t_dispatch,
                 t_start, n_rows, n_tokens, is_decode, wall_clock_s) = ev
                for rid in rids:
                    qs = m.queue_start.pop(rid, (t_dispatch, node))
                    m.add_span(rid, "queue", qs[0], t_dispatch, node, stage)
                    m.add_span(rid, "batch_wait", t_dispatch, t_start, node,
                               stage)
                    m.add_span(rid, "compute", t_start, t, node, stage,
                               {"decode": is_decode})
                    m.cursor[rid] = t
                m.counters.append((t, "queue_depth", node, float(queue_depth)))
                key = (stage, "decode" if is_decode else "prefill")
                cw = m.compute_wall.get(key)
                if cw is None:
                    cw = m.compute_wall[key] = _ComputeWall()
                cw.wall_s += wall_clock_s
                cw.calls += 1
                cw.rows += n_rows
                cw.live_rows += len(rids)
                cw.tokens += n_tokens
                cw.gflops += gflops
                m.batches.append(
                    (t_start, t, node, stage, len(rids), n_rows, is_decode)
                )
                clock.advance(t)
            elif op == "submit":
                _, t, rid, ed, arrival = ev
                if rid not in m.arrival:
                    m.arrival[rid] = arrival
                    m.attempts[rid] = 1
                    # admission wait: ED arrival -> first-hop submission
                    # (zero today; deadline-aware admission control will
                    # stretch it)
                    m.add_span(rid, "admission", arrival, t, ed, 0)
                    m.cursor[rid] = t
                    clock.advance(t)
            elif op == "resubmit":
                # fail-stop re-execution: close the open wait as lost time,
                # restart the tiling cursor at the re-submission instant
                _, t, rid = ev
                qs = m.queue_start.pop(rid, None)
                cur = m.cursor.get(rid, t)
                if qs is not None:
                    m.add_span(rid, "queue", qs[0], t, qs[1], -1,
                               {"lost": True})
                elif t > cur:
                    # in flight / in service when the failure landed: the
                    # preceding span already tiles up to the detection event
                    # in the engine; anything left is unattributed lost time
                    m.add_span(rid, "queue", cur, t, -1, -1, {"lost": True})
                m.cursor[rid] = t
                m.attempts[rid] = m.attempts.get(rid, 0) + 1
                m.instants.append(
                    {"t": t, "kind": "resubmit", "rid": rid, "node": -1,
                     "stage": -1, "attempt": m.attempts[rid]}
                )
                clock.advance(t)
            elif op == "exit":
                _, t, rid, stage, conf = ev
                m.done[rid] = t
                m.queue_start.pop(rid, None)
                m.cursor[rid] = t
                m.instants.append(
                    {"t": t, "kind": "retire", "rid": rid, "node": -1,
                     "stage": stage, "conf": conf}
                )
                clock.advance(t)
            elif op == "fail":
                _, t, node = ev
                m.instants.append(
                    {"t": t, "kind": "failure", "rid": -1, "node": node,
                     "stage": -1}
                )
                clock.advance(t)
            elif op == "span":
                _, rid, kind, t0, t1, node, stage, attrs = ev
                m.add_span(rid, kind, t0, t1, node, stage, attrs)
                clock.advance(t1)
            elif op == "inst":
                _, t, kind, rid, node, stage, attrs = ev
                m.instants.append(
                    {"t": t, "kind": kind, "rid": rid, "node": node,
                     "stage": stage, **attrs}
                )
                clock.advance(t)
            elif op == "ctr":
                _, t, name, node, value = ev
                m.counters.append((t, name, node, float(value)))
                clock.advance(t)
        self._mat = m
        self._n_mat = len(self._events)
        return m

    # materialized state, replayed on demand
    @property
    def spans(self) -> dict[int, list[Span]]:
        return self._replay().spans

    @property
    def instants(self) -> list[dict]:
        return self._replay().instants

    @property
    def counters(self) -> list[tuple[float, str, int, float]]:
        return self._replay().counters

    @property
    def compute_wall(self) -> dict[tuple[int, str], _ComputeWall]:
        return self._replay().compute_wall

    @property
    def arrival(self) -> dict[int, float]:
        return self._replay().arrival

    @property
    def done(self) -> dict[int, float]:
        return self._replay().done

    @property
    def attempts(self) -> dict[int, int]:
        return self._replay().attempts

    @property
    def batches(self) -> list[tuple]:
        return self._replay().batches

    # -- views --------------------------------------------------------------
    def closed(self, rid: int) -> bool:
        return rid in self._replay().done

    def components(self, rid: int) -> dict[str, float]:
        """Per-kind span-duration sums of one request's tree."""
        out = {k: 0.0 for k in SPAN_KINDS}
        for s in self._replay().spans.get(rid, ()):
            out[s.kind] = out.get(s.kind, 0.0) + s.duration
        return out

    def check_tree(self, rid: int) -> list[str]:
        """Invariant check of one request's span tree; returns violations.

        A closed tree tiles ``[arrival, done]`` contiguously: every span
        starts exactly (float equality) where its predecessor ended, spans
        are monotone (t1 >= t0), and the endpoints match the request's
        recorded arrival / retirement.
        """
        m = self._replay()
        errs: list[str] = []
        spans = m.spans.get(rid)
        if not spans:
            return [f"rid {rid}: no spans"]
        if rid not in m.done:
            errs.append(f"rid {rid}: tree never closed (no retirement)")
        for i, s in enumerate(spans):
            if not (s.t1 >= s.t0):
                errs.append(f"rid {rid} span {i} ({s.kind}): t1 < t0")
            if i and spans[i - 1].t1 != s.t0:
                errs.append(
                    f"rid {rid} span {i} ({s.kind}): starts at {s.t0!r}, "
                    f"previous ended at {spans[i - 1].t1!r}"
                )
        if rid in m.arrival and spans[0].t0 != m.arrival[rid]:
            errs.append(f"rid {rid}: first span does not start at arrival")
        if rid in m.done and spans[-1].t1 != m.done[rid]:
            errs.append(f"rid {rid}: last span does not end at retirement")
        return errs


class NullTracer:
    """Zero-cost stub: every hook is a no-op.  The engine never calls into a
    tracer unless one is attached, so this exists for call sites that want
    an unconditional object (e.g. library code taking ``tracer=NullTracer()``)."""

    wants_wall_clock = False

    def __getattr__(self, name: str):
        if name.startswith("on_") or name.startswith("add_"):
            return self._noop
        raise AttributeError(name)

    @staticmethod
    def _noop(*args: Any, **kwargs: Any) -> None:
        return None
