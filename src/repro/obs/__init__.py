"""Observability for the serving engine: spans, metrics, exporters.

See ``src/repro/serving/README.md`` ("Observability") for the
instrumentation-point diagram and how the pieces compose.
"""
from repro.obs.attribution import attribution_report, decompose
from repro.obs.export import chrome_trace, validate_chrome_trace, write_chrome_trace
from repro.obs.metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsCollector,
    MetricsRegistry,
)
from repro.obs.roofline_hook import roofline_utilization
from repro.obs.stream import HOOKS, InstrumentationStream, build_stream
from repro.obs.trace import SPAN_KINDS, NullTracer, SimClock, Span, SpanTracer

__all__ = [
    "attribution_report",
    "decompose",
    "chrome_trace",
    "validate_chrome_trace",
    "write_chrome_trace",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsCollector",
    "MetricsRegistry",
    "roofline_utilization",
    "HOOKS",
    "InstrumentationStream",
    "build_stream",
    "SPAN_KINDS",
    "NullTracer",
    "SimClock",
    "Span",
    "SpanTracer",
]
