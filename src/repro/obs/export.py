"""Exporters: Chrome-trace/Perfetto JSON from a :class:`SpanTracer`.

The Trace Event Format (the JSON Chrome's ``about:tracing`` and Perfetto's
legacy importer read) wants microsecond ``ts``/``dur`` integers, ``"X"``
complete events for spans, ``"i"`` instants, ``"C"`` counter samples, and
``"M"`` metadata naming the process/thread tracks.  We lay the serve out as

  * one ``pid`` per request track group (``pid=1`` "requests"), one ``tid``
    per request id — a request's span tiling reads left-to-right with no
    gaps;
  * one ``pid`` per serving node (``pid = 1000 + node``), ``tid=0`` the
    replica's batch busy track (each dispatched batch one ``X`` event);
  * counter events (queue depth, pool occupancy) on the node pids.

Timestamps are simulated seconds scaled by 1e6 — open the file in
https://ui.perfetto.dev and the timeline is the simulated serve.

``validate_chrome_trace`` is the invariant checker behind
``tools/check_trace.py`` and the CI gate: schema well-formedness, no
unclosed/backwards spans, per-request tracks monotone and non-overlapping.
"""
from __future__ import annotations

import json
from typing import Any

__all__ = ["chrome_trace", "write_chrome_trace", "validate_chrome_trace"]

_REQ_PID = 1
_NODE_PID0 = 1000
_US = 1e6  # simulated seconds -> trace microseconds
#: adjacent spans share their boundary float in seconds, but ts/dur are
#: rounded to 1 ns in the export — neighbours may disagree by one quantum
_ROUND_SLOP_US = 2e-3


def _us(t: float) -> float:
    return round(float(t) * _US, 3)


def chrome_trace(tracer) -> dict:
    """Trace Event Format payload (``{"traceEvents": [...]}``) of a serve."""
    ev: list[dict] = [
        {"ph": "M", "pid": _REQ_PID, "name": "process_name",
         "args": {"name": "requests"}},
    ]
    nodes_seen: set[int] = set()

    def node_pid(node: int) -> int:
        if node not in nodes_seen:
            nodes_seen.add(node)
            ev.append({"ph": "M", "pid": _NODE_PID0 + node,
                       "name": "process_name", "args": {"name": f"node{node}"}})
        return _NODE_PID0 + node

    for rid in sorted(tracer.spans):
        ev.append({"ph": "M", "pid": _REQ_PID, "tid": rid,
                   "name": "thread_name", "args": {"name": f"req{rid}"}})
        for s in tracer.spans[rid]:
            args: dict[str, Any] = {"node": s.node, "stage": s.stage}
            if s.attrs:
                args.update(s.attrs)
            ev.append({
                "ph": "X", "pid": _REQ_PID, "tid": rid, "name": s.kind,
                "cat": "request", "ts": _us(s.t0), "dur": _us(s.duration),
                "args": args,
            })

    for (t_start, t_done, node, stage, live, rows, is_decode) in tracer.batches:
        ev.append({
            "ph": "X", "pid": node_pid(node), "tid": 0,
            "name": f"stage{stage}.{'decode' if is_decode else 'prefill'}",
            "cat": "batch", "ts": _us(t_start), "dur": _us(t_done - t_start),
            "args": {"live": live, "rows": rows},
        })

    for inst in tracer.instants:
        pid, tid = (_REQ_PID, inst["rid"])
        if inst["rid"] < 0 and inst["node"] >= 0:
            pid, tid = node_pid(inst["node"]), 0
        args = {k: v for k, v in inst.items() if k not in ("t", "kind")}
        ev.append({"ph": "i", "pid": pid, "tid": tid, "name": inst["kind"],
                   "cat": "event", "ts": _us(inst["t"]), "s": "t",
                   "args": args})

    for (t, name, node, value) in tracer.counters:
        ev.append({"ph": "C", "pid": node_pid(node), "tid": 0, "name": name,
                   "ts": _us(t), "args": {"value": value}})

    return {"traceEvents": ev, "displayTimeUnit": "ms"}


def write_chrome_trace(path: str, tracer) -> dict:
    payload = chrome_trace(tracer)
    with open(path, "w") as f:
        json.dump(payload, f)
    return payload


def validate_chrome_trace(payload: Any) -> list[str]:
    """Schema / invariant violations of a Trace Event Format payload.

    Checks: top-level shape, per-event required fields by phase, non-negative
    ``X`` durations, balanced ``B``/``E`` stacks per track, and per-request
    span tracks (``pid == 1``) monotone and non-overlapping.
    """
    errs: list[str] = []
    if not isinstance(payload, dict) or "traceEvents" not in payload:
        return ["payload is not an object with a traceEvents list"]
    events = payload["traceEvents"]
    if not isinstance(events, list):
        return ["traceEvents is not a list"]
    if not events:
        errs.append("traceEvents is empty")

    open_stacks: dict[tuple, int] = {}
    req_tracks: dict[tuple, list[tuple[float, float]]] = {}
    for i, e in enumerate(events):
        if not isinstance(e, dict):
            errs.append(f"event {i}: not an object")
            continue
        ph = e.get("ph")
        if ph not in ("X", "B", "E", "i", "I", "C", "M"):
            errs.append(f"event {i}: unknown phase {ph!r}")
            continue
        if "pid" not in e:
            errs.append(f"event {i}: missing pid")
        if ph != "M":
            ts = e.get("ts")
            if not isinstance(ts, (int, float)):
                errs.append(f"event {i}: missing/non-numeric ts")
                continue
        if ph == "M":
            continue
        if "name" not in e:
            errs.append(f"event {i}: missing name")
        key = (e.get("pid"), e.get("tid"))
        if ph == "X":
            dur = e.get("dur")
            if not isinstance(dur, (int, float)):
                errs.append(f"event {i}: X event missing dur")
            elif dur < 0:
                errs.append(f"event {i}: negative duration {dur}")
            elif e.get("pid") == _REQ_PID:
                req_tracks.setdefault(key, []).append((ts, ts + dur))
        elif ph == "B":
            open_stacks[key] = open_stacks.get(key, 0) + 1
        elif ph == "E":
            n = open_stacks.get(key, 0)
            if n == 0:
                errs.append(f"event {i}: E without matching B on track {key}")
            else:
                open_stacks[key] = n - 1

    for key, n in open_stacks.items():
        if n:
            errs.append(f"track {key}: {n} unclosed B span(s)")

    for (pid, tid), ivals in req_tracks.items():
        prev_end = None
        for (t0, t1) in ivals:  # events were emitted in span order
            if prev_end is not None and t0 < prev_end - _ROUND_SLOP_US:
                errs.append(
                    f"request track tid={tid}: span at ts={t0} overlaps "
                    f"previous span ending at {prev_end}"
                )
            prev_end = t1
    return errs
