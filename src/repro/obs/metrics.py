"""Metrics registry: counters, gauges, fixed-bucket log-scale histograms.

The registry is deliberately boring — named metric objects with O(1) updates
and a JSON-able ``snapshot()`` — so it can sit on the serving hot path.
:class:`MetricsCollector` is the instrumentation-stream subscriber that feeds
one: per-replica batch occupancy and padded-row waste, queue depths,
block-pool occupancy and prefix-hit rate, delay / service / transfer
histograms (p50/p95/p99 from log-scale buckets), and the realized
``(confidence, exit_stage)`` pairs the control plane needs to recalibrate
exit profiles online (ROADMAP: "a control plane that learns").

Histogram buckets are fixed at construction (log-spaced, ``per_decade``
buckets per decade of seconds) so observation is one ``bisect`` into a small
sorted list and two scalar adds — no allocation, no resizing, mergeable
across replicas/serves by bucket-count addition.
"""
from __future__ import annotations

import dataclasses
from bisect import bisect_right
from typing import Any

import numpy as np

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "MetricsCollector",
]


@dataclasses.dataclass
class Counter:
    name: str
    value: float = 0.0

    def inc(self, v: float = 1.0) -> None:
        # float() keeps numpy scalars out: one np.float64 would infect the
        # accumulator and make every later += pay numpy-scalar dispatch
        self.value += float(v)

    def snapshot(self) -> dict:
        return {"type": "counter", "value": self.value}


@dataclasses.dataclass
class Gauge:
    name: str
    value: float = float("nan")
    max_value: float = float("-inf")
    n_samples: int = 0
    _sum: float = 0.0

    def set(self, v: float) -> None:
        v = float(v)  # numpy-scalar comparisons cost ~10x a float compare
        self.value = v
        self.n_samples += 1
        self._sum += v
        if v > self.max_value:
            self.max_value = v

    @property
    def mean(self) -> float:
        return self._sum / self.n_samples if self.n_samples else float("nan")

    def snapshot(self) -> dict:
        return {
            "type": "gauge",
            "value": self.value,
            "max": self.max_value if self.n_samples else float("nan"),
            "mean": self.mean,
            "n": self.n_samples,
        }


class Histogram:
    """Fixed log-scale buckets over ``[10**lo_decade, 10**hi_decade]``.

    Bucket 0 catches everything below the range (including zeros), the last
    bucket everything above; quantiles interpolate within a bucket on a log
    scale, so p50/p95/p99 are exact to bucket resolution (default: 8 buckets
    per decade ~ 33% worst-case ratio error, far below the decade-scale
    spreads tail-latency work cares about).
    """

    def __init__(
        self, name: str, lo_decade: int = -7, hi_decade: int = 3,
        per_decade: int = 8,
    ):
        self.name = name
        self.bounds = np.logspace(
            lo_decade, hi_decade, (hi_decade - lo_decade) * per_decade + 1
        )
        # plain-Python mirrors keep observe() off numpy's scalar paths (the
        # histogram sits on the serving hot path: the tracing A/B budget)
        self._bounds = self.bounds.tolist()
        self.counts = [0] * (self.bounds.size + 1)
        self.n = 0
        self.sum = 0.0
        self.min = float("inf")
        self.max = float("-inf")

    def observe(self, v: float) -> None:
        # float() first: bisecting with an np.float64 key would pay a
        # numpy-scalar __lt__ per probe (~10x a float compare)
        v = float(v)
        self.counts[bisect_right(self._bounds, v)] += 1
        self.n += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    @property
    def mean(self) -> float:
        return self.sum / self.n if self.n else float("nan")

    def quantile(self, q: float) -> float:
        """Approximate quantile from the bucket counts (log interpolation)."""
        if self.n == 0:
            return float("nan")
        target = q * self.n
        acc = 0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            if acc + c >= target:
                frac = (target - acc) / c
                lo = self.bounds[i - 1] if i >= 1 else self.min
                hi = self.bounds[i] if i < self.bounds.size else self.max
                lo = max(min(lo, self.max), min(self.min, hi))
                if lo <= 0 or hi <= 0:
                    return lo + frac * (hi - lo)
                return float(lo * (hi / lo) ** frac)
            acc += c
        return self.max

    def snapshot(self) -> dict:
        return {
            "type": "histogram",
            "n": self.n,
            "mean": self.mean,
            "min": self.min if self.n else float("nan"),
            "max": self.max if self.n else float("nan"),
            "p50": self.quantile(0.50),
            "p95": self.quantile(0.95),
            "p99": self.quantile(0.99),
        }


class MetricsRegistry:
    """Named metrics with get-or-create accessors and a JSON snapshot."""

    def __init__(self):
        self._metrics: dict[str, Any] = {}

    def _get(self, name: str, factory):
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = factory(name)
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str, **kw) -> Histogram:
        return self._get(name, lambda n: Histogram(n, **kw))

    def names(self) -> list[str]:
        return sorted(self._metrics)

    def snapshot(self) -> dict:
        return {name: self._metrics[name].snapshot() for name in self.names()}


class MetricsCollector:
    """Instrumentation-stream subscriber feeding a :class:`MetricsRegistry`.

    Attach to ``serve(metrics=...)`` alongside (or instead of) a tracer;
    unlike the tracer it keeps no per-request span lists, only aggregates —
    cheap enough to leave on for every serve.
    """

    wants_wall_clock = False

    def __init__(self, registry: MetricsRegistry | None = None):
        self.registry = registry or MetricsRegistry()
        #: realized (confidence, exit_stage) pairs — the control plane's
        #: raw material for online exit-profile recalibration
        self.exit_pairs: list[tuple[float, int]] = []
        self._arrival: dict[int, float] = {}
        # hot metrics resolved once (hooks fire per event; registry lookups
        # per call would dominate the tracing A/B budget)
        r = self.registry
        self._h_transfer = r.histogram("transfer_s")
        self._h_delay = r.histogram("delay_s")
        self._h_service = r.histogram("batch_service_s")
        self._c_submitted = r.counter("requests_submitted")
        self._c_batches = r.counter("batches")
        self._c_fwd_rows = r.counter("forward_rows")
        self._c_real_rows = r.counter("real_rows")
        self._g_occupancy: dict[int, Gauge] = {}
        self._g_depth: dict[int, Gauge] = {}
        self._g_pool: dict[int, Gauge] = {}
        self._c_exits: dict[int, Counter] = {}

    # -- hooks --------------------------------------------------------------
    def on_submit(self, t: float, rid: int, ed: int, arrival: float) -> None:
        if rid not in self._arrival:
            self._arrival[rid] = arrival
            self._c_submitted.inc()

    def on_resubmit(self, t: float, rid: int) -> None:
        self.registry.counter("requests_resubmitted").inc()

    def on_transfer(
        self, t0: float, t1: float, wall: float, src: int, dst: int,
        rid: int, mb: float,
    ) -> None:
        self._h_transfer.observe(wall)

    def on_loopback(
        self, t0: float, t1: float, src: int, dst: int, rid: int, mb: float
    ) -> None:
        self._h_transfer.observe(t1 - t0)

    def on_batch(
        self,
        t: float,
        node: int,
        gflops: float,
        wall: float,
        queue_depth: int,
        *,
        rids: tuple = (),
        n_rows: int = 0,
        is_decode: bool = False,
        **_: Any,
    ) -> None:
        self._c_batches.inc()
        self._c_fwd_rows.inc(n_rows)
        self._c_real_rows.inc(len(rids))
        self._h_service.observe(wall)
        if n_rows:
            g = self._g_occupancy.get(node)
            if g is None:
                g = self._g_occupancy[node] = self.registry.gauge(
                    f"batch_occupancy.node{node}"
                )
            g.set(len(rids) / n_rows)
        g = self._g_depth.get(node)
        if g is None:
            g = self._g_depth[node] = self.registry.gauge(
                f"queue_depth.node{node}"
            )
        g.set(queue_depth)

    def on_pool(
        self, t: float, node: int, used_fraction: float,
        hit_blocks: int = 0, total_blocks: int = 0,
    ) -> None:
        g = self._g_pool.get(node)
        if g is None:
            g = self._g_pool[node] = self.registry.gauge(
                f"pool_occupancy.node{node}"
            )
        g.set(used_fraction)
        if total_blocks:
            self.registry.counter("prefix_hit_blocks").inc(hit_blocks)
            self.registry.counter("prefix_total_blocks").inc(total_blocks)

    def on_exit(self, t: float, rid: int, stage: int, conf: float) -> None:
        c = self._c_exits.get(stage)
        if c is None:
            c = self._c_exits[stage] = self.registry.counter(
                f"exits.stage{stage}"
            )
        c.inc()
        self.exit_pairs.append((float(conf), int(stage)))
        arrival = self._arrival.get(rid)
        if arrival is not None:
            self._h_delay.observe(t - arrival)

    def on_failure(self, t: float, node: int) -> None:
        self.registry.counter("node_failures").inc()

    # -- views --------------------------------------------------------------
    def padded_row_frac(self) -> float:
        fwd = self.registry.counter("forward_rows").value
        real = self.registry.counter("real_rows").value
        return 1.0 - real / fwd if fwd else 0.0

    def realized_exit_histogram(self) -> dict[int, int]:
        out: dict[int, int] = {}
        for _, stage in self.exit_pairs:
            out[stage] = out.get(stage, 0) + 1
        return out

    def snapshot(self) -> dict:
        return {
            "metrics": self.registry.snapshot(),
            "padded_row_frac": self.padded_row_frac(),
            "exit_histogram": {
                str(k): v for k, v in sorted(self.realized_exit_histogram().items())
            },
            "num_exit_pairs": len(self.exit_pairs),
        }
