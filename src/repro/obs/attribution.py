"""Delay attribution: measured span sums vs the paper's delay model.

Two layers:

:func:`decompose`
    Pure bookkeeping over a :class:`~repro.obs.trace.SpanTracer` — for every
    closed request, sum its span tree by component (admission / transfer /
    queue / batch_wait / compute), check the sum reconciles with the
    engine-reported delay (the tiling invariant), and aggregate per-stage
    and per-node means.

:func:`attribution_report`
    Joins the measured decomposition with the DTO-EE model terms the
    optimizer actually minimizes (paper Eqs. 4/6/8): per node, the M/D/1-PS
    sojourn ``alpha/(mu - lam)`` at the steady-state flows vs the measured
    per-visit sojourn (queue + batch_wait + compute at that node); per
    request, the aggregate queue/compute/comms split vs the model's
    ``sum_j lam_j/(mu_j - lam_j)/Phi + sum_e phi_e * T^cm_e / Phi``.  The
    per-node relative error is the number the BENCH gate watches: when it
    drifts, the model DTO-EE optimizes no longer describes the engine.
"""
from __future__ import annotations

import numpy as np

from repro.core.queueing import (
    alpha_per_node,
    steady_state_flows,
    transmission_delay_per_edge,
)
from repro.obs.trace import SPAN_KINDS

__all__ = ["decompose", "attribution_report"]

#: span kinds spent *at a serving node* — measured counterpart of the
#: model's M/D/1-PS sojourn
NODE_KINDS = ("queue", "batch_wait", "compute")


def decompose(tracer, stats=None, tol: float = 1e-6) -> dict:
    """Measured delay decomposition of one serve.

    Returns a JSON-able dict with per-request component sums, the
    reconciliation residual |sum(components) - reported delay| (must vanish:
    the span tiling is exact), and per-stage / per-node component means.
    """
    reported: dict[int, float] = {}
    if stats is not None:  # ServeStats keeps parallel rid/delay lists
        for rid, delay in zip(
            getattr(stats, "rids", ()), getattr(stats, "delays", ())
        ):
            reported[int(rid)] = float(delay)

    per_request: list[dict] = []
    totals = {k: 0.0 for k in SPAN_KINDS}
    # node -> [sum queue, sum batch_wait, sum compute, visits]
    node_acc: dict[int, list[float]] = {}
    stage_acc: dict[int, dict[str, float]] = {}
    max_residual = 0.0
    n_lost = 0

    for rid, spans in tracer.spans.items():
        if not tracer.closed(rid):
            continue
        comp = {k: 0.0 for k in SPAN_KINDS}
        lost = 0.0
        for s in spans:
            if s.attrs and s.attrs.get("lost"):
                lost += s.duration
                continue
            comp[s.kind] += s.duration
            if s.kind in NODE_KINDS and s.node >= 0:
                acc = node_acc.setdefault(s.node, [0.0, 0.0, 0.0, 0])
                acc[NODE_KINDS.index(s.kind)] += s.duration
                if s.kind == "compute":
                    acc[3] += 1
            if s.kind in NODE_KINDS and s.stage >= 0:
                sacc = stage_acc.setdefault(
                    s.stage, {k: 0.0 for k in NODE_KINDS} | {"visits": 0}
                )
                sacc[s.kind] += s.duration
                if s.kind == "compute":
                    sacc["visits"] += 1
        if lost:
            n_lost += 1
        # normalize to Python floats: engine timestamps can be np.float64
        # (arrival times come off np.cumsum) and the reports must JSON-dump
        comp = {k: float(v) for k, v in comp.items()}
        lost = float(lost)
        total = sum(comp.values()) + lost
        span_delay = float(spans[-1].t1 - spans[0].t0)
        entry = {"rid": rid, **comp, "lost": lost, "total": total}
        if rid in reported:
            entry["reported_delay"] = reported[rid]
            entry["residual"] = abs(total - reported[rid])
            max_residual = max(max_residual, entry["residual"])
        else:
            entry["residual"] = abs(total - span_delay)
            max_residual = max(max_residual, entry["residual"])
        per_request.append(entry)
        for k in SPAN_KINDS:
            totals[k] += comp[k]

    n = len(per_request)
    per_node = {
        int(node): {
            "queue_s": float(acc[0]),
            "batch_wait_s": float(acc[1]),
            "compute_s": float(acc[2]),
            "visits": acc[3],
            "sojourn_per_visit_s": float(sum(acc[:3]) / acc[3]) if acc[3] else 0.0,
        }
        for node, acc in sorted(node_acc.items())
    }
    per_stage = {
        int(stage): {
            "queue_mean_s": float(acc["queue"] / acc["visits"]) if acc["visits"] else 0.0,
            "batch_wait_mean_s": float(acc["batch_wait"] / acc["visits"]) if acc["visits"] else 0.0,
            "compute_mean_s": float(acc["compute"] / acc["visits"]) if acc["visits"] else 0.0,
            "visits": acc["visits"],
        }
        for stage, acc in sorted(stage_acc.items())
    }
    return {
        "num_requests": n,
        "num_with_lost_time": n_lost,
        "max_residual_s": float(max_residual),
        "reconciles": bool(max_residual <= tol),
        "mean_components_s": {
            k: float(totals[k] / n) if n else 0.0 for k in SPAN_KINDS
        },
        "per_stage": per_stage,
        "per_node": per_node,
        "per_request": per_request,
    }


def attribution_report(tracer, p, topo, profile, I_node, stats=None) -> dict:
    """Measured vs DTO-EE-model delay attribution.

    ``p, topo, profile, I_node`` are exactly the optimizer's inputs (offload
    probabilities, topology, model profile, per-node remaining ratios), so
    the model side is the same expression DTO-EE minimized.
    """
    meas = decompose(tracer, stats)
    phi, lam = steady_state_flows(np.asarray(p, np.float32), topo, profile, I_node)
    phi = np.asarray(phi, np.float64)
    lam = np.asarray(lam, np.float64)
    alpha_n = alpha_per_node(topo, profile)
    mu = np.where(np.isinf(topo.mu), 1e30, np.asarray(topo.mu, np.float64))
    gap = mu - lam
    es = topo.node_stage > 0

    # model per-visit terms on each ES (Eq. 6 split into service + wait)
    sojourn = np.where(es & (gap > 0), alpha_n / np.where(gap > 0, gap, 1.0), 0.0)
    service = np.where(es, alpha_n / mu, 0.0)
    wait = sojourn - service

    per_node = {}
    for j in np.flatnonzero(es):
        j = int(j)
        m = meas["per_node"].get(j)
        model_sojourn = float(sojourn[j])
        entry = {
            "model_sojourn_s": model_sojourn,
            "model_compute_s": float(service[j]),
            "model_queue_s": float(wait[j]),
            "model_lam_gflops": float(lam[j]),
            "measured_sojourn_s": m["sojourn_per_visit_s"] if m else 0.0,
            "visits": m["visits"] if m else 0,
        }
        if m and model_sojourn > 0:
            entry["rel_error"] = (
                m["sojourn_per_visit_s"] - model_sojourn
            ) / model_sojourn
        per_node[j] = entry

    # aggregate per-request split (model: Eq. 8 decomposed)
    total_phi = float(np.asarray(topo.phi_ext, np.float64).sum())
    t_cm = np.asarray(transmission_delay_per_edge(topo, profile), np.float64)
    I_np = np.asarray(I_node, np.float64)
    phi_edge = np.asarray(p, np.float64) * phi[topo.edge_src] * I_np[topo.edge_src]
    model_comms = float((phi_edge * t_cm).sum() / total_phi) if total_phi else 0.0
    model_node = float((lam[es] / np.where(gap[es] > 0, gap[es], np.inf)).sum()
                       / total_phi) if total_phi else 0.0
    model_compute = float((phi[es] * alpha_n[es] / mu[es]).sum() / total_phi) \
        if total_phi else 0.0

    mc = meas["mean_components_s"]
    measured_node = mc["queue"] + mc["batch_wait"] + mc["compute"]
    report = {
        "measured": {
            "queue_s": mc["queue"] + mc["batch_wait"],
            "compute_s": mc["compute"],
            "comms_s": mc["transfer"],
            "admission_s": mc["admission"],
            "total_s": sum(mc.values()),
        },
        "model": {
            "queue_s": model_node - model_compute,
            "compute_s": model_compute,
            "comms_s": model_comms,
            "total_s": model_node + model_comms,
        },
        "rel_error": {
            "node_sojourn": (measured_node - model_node) / model_node
            if model_node else float("nan"),
            "comms": (mc["transfer"] - model_comms) / model_comms
            if model_comms else float("nan"),
        },
        "per_node": per_node,
        "reconciles": meas["reconciles"],
        "max_residual_s": meas["max_residual_s"],
        "num_requests": meas["num_requests"],
    }
    return report
