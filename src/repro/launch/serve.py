"""Collaborative serving driver: ``python -m repro.launch.serve --arch <id>``.

Boots a reduced model, partitions it into stages over a small edge topology,
runs DTO-EE configuration phases between time slots, and serves Poisson
request streams through the REAL model with live early-exit confidences.

Two control-plane modes:

  * default — the paper's slotted loop: one configuration phase BEFORE each
    slot's serve, capacities re-randomized between slots;
  * ``--reconfig-interval R`` (and/or ``--scenario``) — the ONLINE loop: one
    long serve during which telemetry feeds a ReconfigController that
    re-optimizes p/thresholds every R simulated seconds while a scenario
    perturbs the live environment.

Observability flags (see src/repro/serving/README.md, "Observability"):

  * ``--trace-out trace.json`` — attach a SpanTracer and write the serve as
    Chrome-trace/Perfetto JSON (open at https://ui.perfetto.dev).  Slotted
    mode traces the LAST slot (one trace file, one serve).
  * ``--stats-report report.json`` — write the machine-readable
    ``ServeStats.report()`` (summary + per-request delay decomposition +
    metrics registry snapshot) of the traced serve.
"""
from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro.configs import get_config
from repro.control import (
    ControllerConfig,
    ReconfigController,
    SCENARIO_NAMES,
    Telemetry,
    TelemetryConfig,
    get_scenario,
)
from repro.core import dto_ee
from repro.core.profiles import profile_from_arch
from repro.core.thresholds import synthetic_validation
from repro.core.topology import build_edge_network, NetworkSpec, with_resampled_capacities
from repro.core.types import DtoHyperParams
from repro.data import RequestConfig, poisson_requests
from repro.models import model as model_lib
from repro.serving import CollaborativeEngine


def _observers(args):
    """(tracer, metrics) when an observability flag asked for them."""
    if args.trace_out is None and args.stats_report is None:
        return None, None
    from repro.obs import MetricsCollector, SpanTracer

    return SpanTracer(), MetricsCollector()


def _write_obs(args, stats) -> None:
    if args.trace_out and stats.trace is not None:
        from repro.obs import write_chrome_trace

        write_chrome_trace(args.trace_out, stats.trace)
        print(f"trace: {args.trace_out}", flush=True)
    if args.stats_report:
        with open(args.stats_report, "w") as f:
            json.dump(stats.report(), f, indent=1)
        print(f"stats report: {args.stats_report}", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--slot-seconds", type=float, default=5.0)
    ap.add_argument("--requests-per-slot", type=int, default=24)
    ap.add_argument("--num-eds", type=int, default=8)
    ap.add_argument(
        "--batch-size",
        type=int,
        default=8,
        help="per-replica micro-batch width for the data plane",
    )
    ap.add_argument(
        "--gen-len",
        type=int,
        default=1,
        help="tokens decoded per request (1 = single-shot classification)",
    )
    ap.add_argument(
        "--decode-mode",
        choices=("cached", "stateless"),
        default=None,
        help="cached = slot-resident KV caches + continuous batching; "
        "stateless = re-prefill baseline (default: cached iff gen-len > 1)",
    )
    ap.add_argument(
        "--num-slots",
        type=int,
        default=None,
        help="cache slots per replica ring (default: 2 * batch size)",
    )
    ap.add_argument(
        "--cache-layout",
        choices=("dense", "paged"),
        default="dense",
        help="slot-store memory layout: dense worst-case arenas, or paged "
        "block pools with prompt-prefix sharing (token-identical outputs)",
    )
    ap.add_argument(
        "--block-size",
        type=int,
        default=16,
        help="tokens per KV block under --cache-layout paged",
    )
    ap.add_argument(
        "--num-blocks",
        type=int,
        default=None,
        help="KV blocks per replica pool (default: the dense footprint)",
    )
    ap.add_argument(
        "--no-prefix-sharing",
        action="store_true",
        help="disable prompt-prefix block sharing under the paged layout",
    )
    ap.add_argument(
        "--reconfig-interval",
        type=float,
        default=None,
        metavar="SECONDS",
        help="enable the ONLINE control plane: run one long serve and let a "
        "ReconfigController re-optimize p/thresholds from live telemetry "
        "every SECONDS of simulated time (atomic install after the "
        "decision time; hysteresis skips quiet environments)",
    )
    ap.add_argument(
        "--reconfig-rounds",
        type=int,
        default=30,
        help="DTO-EE rounds per online configuration phase (decision time = "
        "rounds x 2 ms)",
    )
    ap.add_argument(
        "--scenario",
        choices=SCENARIO_NAMES,
        default=None,
        help="perturb the live environment mid-serve: 'burst' (a subset of "
        "EDs floods 3x), 'slowdown' (the busiest stage-1 replica throttles "
        "to 15%% of nameplate), 'link' (its uplinks degrade 10x), 'failure' "
        "(it fail-stops; tasks re-execute from their EDs — needs "
        "--gen-len 1).  Implies the online serve mode.",
    )
    ap.add_argument(
        "--batch-policy",
        choices=("fifo", "threshold"),
        default="fifo",
        help="batch formation: 'fifo' (arrival order), or 'threshold' — "
        "threshold-aware packing that groups rows by predicted exit stage "
        "(confidence history vs the live DTO-EE thresholds) and trims "
        "batches to exact padded shapes; token-identical outputs, lower "
        "padded-row waste",
    )
    ap.add_argument(
        "--trace-out",
        default=None,
        metavar="PATH",
        help="write a Chrome-trace/Perfetto JSON of the serve (the last "
        "slot in slotted mode) to PATH",
    )
    ap.add_argument(
        "--stats-report",
        default=None,
        metavar="PATH",
        help="write the machine-readable ServeStats.report() JSON (summary "
        "+ delay decomposition + metrics) of the traced serve to PATH",
    )
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params = model_lib.init_params(jax.random.key(args.seed), cfg)
    profile = profile_from_arch(cfg)
    topo = build_edge_network(
        seed=args.seed,
        profile=profile,
        spec=NetworkSpec(num_eds=args.num_eds, es_per_stage=(3, 4)),
    )
    exit_profile = synthetic_validation(seed=args.seed + 1, profile=profile)
    engine = CollaborativeEngine(
        params, cfg, topo, profile, exit_profile, DtoHyperParams(), seed=args.seed
    )

    rng = np.random.default_rng(args.seed)
    rcfg = RequestConfig(
        arrival_rate=args.requests_per_slot / args.slot_seconds, seed=args.seed
    )
    serve_kw = dict(
        batch_size=args.batch_size,
        gen_len=args.gen_len,
        decode_mode=args.decode_mode,
        num_slots=args.num_slots,
        cache_layout=args.cache_layout,
        block_size=args.block_size,
        num_blocks=args.num_blocks,
        prefix_sharing=not args.no_prefix_sharing,
        batch_policy=args.batch_policy,
    )

    if args.reconfig_interval is not None or args.scenario is not None:
        # ONLINE mode: one long serve, closed-loop reconfiguration mid-flight
        engine.configuration_phase()
        horizon = args.slots * args.slot_seconds
        reqs = poisson_requests(cfg, rcfg, horizon)
        prompts = [tok for _, tok in reqs][: args.requests_per_slot * args.slots]
        span = len(prompts) / rcfg.arrival_rate
        telemetry = Telemetry(
            engine.topo, TelemetryConfig(window_s=args.slot_seconds / 2)
        )
        controller = None
        if args.reconfig_interval is not None:
            controller = ReconfigController(
                telemetry,
                ControllerConfig(
                    interval=args.reconfig_interval, rounds=args.reconfig_rounds
                ),
            )
        scenario = None
        if args.scenario is not None:
            scenario = get_scenario(
                args.scenario, engine.topo, p=engine.p, horizon=span,
                seed=args.seed,
            )
        tracer, metrics = _observers(args)
        stats = engine.serve(
            prompts,
            duration=horizon,
            arrival_rate=rcfg.arrival_rate,
            scenario=scenario,
            controller=controller,
            telemetry=telemetry,
            tracer=tracer,
            metrics=metrics,
            **serve_kw,
        )
        s = stats.summary()
        cap = ", ".join(
            f"{v}: {mu:.1f}" for v, mu in sorted(s["capacity_estimates"].items())
        )
        print(
            f"online: {s['num_completed']} done  "
            f"mean_delay {s['mean_delay']*1e3:.1f}ms  "
            f"std {s['delay_std']*1e3:.1f}ms  p95 {s['p95_delay']*1e3:.1f}ms  "
            f"reconfigs {s['num_reconfigs']}  resubmitted {s['resubmitted']}  "
            f"padded waste {s['padded_row_frac']*100:.1f}%  "
            f"exits {s['exit_histogram']}",
            flush=True,
        )
        print(f"capacity estimates (GFLOP/s): {cap}")
        _write_obs(args, stats)
        print("done")
        return

    stats = None
    for slot in range(args.slots):
        engine.configuration_phase()
        reqs = poisson_requests(cfg, rcfg, args.slot_seconds)
        prompts = [tok for _, tok in reqs][: args.requests_per_slot]
        # observability rides on the LAST slot only: one trace, one serve
        tracer, metrics = (
            _observers(args) if slot == args.slots - 1 else (None, None)
        )
        stats = engine.serve(
            prompts,
            duration=args.slot_seconds,
            arrival_rate=rcfg.arrival_rate,
            tracer=tracer,
            metrics=metrics,
            **serve_kw,
        )
        s = stats.summary()
        paged_info = (
            f"  blocks {s['block_occupancy_peak']*100:.0f}% peak  "
            f"prefix hits {s['prefix_hit_rate']*100:.0f}%"
            if args.cache_layout == "paged"
            else ""
        )
        print(
            f"slot {slot}: {s['num_completed']} done  "
            f"{s['generated_tokens']} tokens  "
            f"mean_delay {s['mean_delay']*1e3:.1f}ms  "
            f"p95 {s['p95_delay']*1e3:.1f}ms  "
            f"padded waste {s['padded_row_frac']*100:.1f}%  "
            f"exits {s['exit_histogram']}  thresholds {engine.thresholds}"
            f"{paged_info}",
            flush=True,
        )
        # dynamic environment: replicas throttle between slots (paper §4.3)
        engine.update_topology(with_resampled_capacities(engine.topo, rng))

    if stats is not None:
        _write_obs(args, stats)
    print("done")


if __name__ == "__main__":
    main()
