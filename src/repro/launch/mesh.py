"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before any jax init).

Single pod : (16, 16)    axes ("data", "model")   — 256 chips (v5e pod)
Multi-pod  : (2, 16, 16) axes ("pod", "data", "model") — 512 chips.  The
"pod" axis is pure data parallelism across the DCN boundary; "data" is
in-pod DP/FSDP; "model" is tensor parallelism inside an ICI-adjacent slice.
"""
from __future__ import annotations

import os

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    # REPRO_MESH=32x8 reshapes the single-pod (data, model) factorization
    # (same 256 chips, different TP degree) — a §Perf iteration knob.
    override = os.environ.get("REPRO_MESH", "")
    if override and not multi_pod:
        d, m = (int(x) for x in override.split("x"))
        shape, axes = (d, m), ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """A 1x1 mesh on whatever single device is present (smoke tests)."""
    return jax.make_mesh((1, 1), ("data", "model"))
