"""End-to-end trainer: ``python -m repro.launch.train --arch <id> [--reduced]``.

Full configs target the production mesh; --reduced trains the smoke-sized
sibling on whatever devices exist (CPU-friendly).  Checkpoints are written
every --ckpt-every steps and restored automatically on relaunch — kill the
process at any step and rerun the same command to resume.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import sharding
from repro.configs import get_config
from repro.data import DataConfig, token_stream
from repro.models import model as model_lib
from repro.runtime import CheckpointManager
from repro.training import AdamWConfig, make_train_step
from repro.training import optimizer as opt_lib


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()

    opt_cfg = AdamWConfig(
        learning_rate=args.lr, total_steps=args.steps, warmup_steps=min(20, args.steps)
    )
    dcfg = DataConfig(batch_size=args.batch, seq_len=args.seq, seed=args.seed)
    step_fn = jax.jit(
        make_train_step(cfg, opt_cfg, microbatches=args.microbatches)
    )

    params = model_lib.init_params(jax.random.key(args.seed), cfg)
    opt_state = opt_lib.init_opt_state(params)
    start_step = 0

    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    if ckpt is not None and ckpt.latest_step() is not None:
        (params, opt_state), manifest = ckpt.restore((params, opt_state))
        start_step = manifest["step"]
        print(f"restored checkpoint at step {start_step}")

    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"{cfg.name}: {n_params/1e6:.1f}M params, {args.steps} steps")

    stream = token_stream(cfg, dcfg, start_step=start_step)
    t0 = time.time()
    for step in range(start_step, args.steps):
        batch = next(stream)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step % 10 == 0 or step == args.steps - 1:
            loss = float(metrics["loss"])
            gn = float(metrics["grad_norm"])
            dt = time.time() - t0
            print(
                f"step {step:5d}  loss {loss:.4f}  grad_norm {gn:.3f}  "
                f"({dt/max(step-start_step+1,1):.2f}s/step)",
                flush=True,
            )
            if not np.isfinite(loss):
                raise RuntimeError("loss diverged")
        if ckpt is not None and (step + 1) % args.ckpt_every == 0:
            path = ckpt.save(step + 1, (params, opt_state), {"arch": cfg.name})
            print(f"checkpoint -> {path}")
    if ckpt is not None:
        ckpt.save(args.steps, (params, opt_state), {"arch": cfg.name})


if __name__ == "__main__":
    main()
