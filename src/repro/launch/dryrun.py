"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Two modes per cell:

  GATE (always)  — the full-depth production program (scan-over-layers)
    is lowered and compiled against the production mesh.  Success proves
    the sharding config is coherent (no mismatched collectives, no
    unpartitionable ops) and memory_analysis proves it fits.

  MEASURE (--fit) — XLA's cost analysis counts while-loop bodies ONCE, so
    exact FLOP/byte/collective totals come from two UNROLLED reduced-depth
    variants (k=1 and k=2 periods per stage) of the same program on the
    same mesh.  Every per-cell cost is linear in the period count
    (identical blocks), so  cost(P) = b + a*P  fits exactly and
    extrapolates to the production depth.  sLSTM's per-timestep recurrence
    (trip count == seq_len, not unrollable) is corrected analytically.

MUST set the host-device override before ANY jax-touching import — jax
locks the device count at first init.
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro import sharding  # noqa: E402
from repro.configs import SHAPES, get_config, input_specs, list_archs  # noqa: E402
from repro.configs.base import shape_applicable  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import layers as layers_lib  # noqa: E402
from repro.models import model as model_lib  # noqa: E402
from repro.roofline import analysis, corrections  # noqa: E402
from repro.roofline.hlo import collective_stats  # noqa: E402
from repro.serving.steps import make_decode_step, make_prefill_step  # noqa: E402
from repro.training import AdamWConfig, make_train_step  # noqa: E402
from repro.training import optimizer as opt_lib  # noqa: E402

ARTIFACT_DIR = os.path.normpath(
    os.path.join(os.path.dirname(__file__), "../../../experiments/dryrun")
)


def _ns(mesh, spec_tree):
    from jax.sharding import NamedSharding

    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree)


def _sds_with(shardings, abstract):
    return jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
        abstract,
        shardings,
    )


def _serving_params(aparams, cfg):
    """Serving checkpoints hold bf16 matrix weights (norm vectors stay f32)."""
    return jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, jnp.bfloat16)
        if (a.dtype == jnp.float32 and len(a.shape) >= 2)
        else a,
        aparams,
    )


def build_lowered(cfg, shape, mesh, microbatches: int = 1, policy: str = "dp_tp"):
    """Lower the cell's step program against ``mesh`` (no compile)."""
    rules = sharding.set_mesh(mesh, policy)
    aparams = model_lib.abstract_params(cfg)
    if shape.mode in ("prefill", "decode") and os.environ.get(
        "REPRO_SERVE_LAYOUT", "replicated"
    ) == "replicated":
        # inference: bf16 weights, TP-only sharding (no per-step FSDP gathers)
        aparams = _serving_params(aparams, cfg)
        pspecs = sharding.param_specs(aparams, rules.as_serving())
    else:
        pspecs = sharding.param_specs(aparams)
    abatch = input_specs(cfg, shape)
    bspecs = sharding.batch_specs(abatch)
    thresholds = jax.ShapeDtypeStruct((len(cfg.exit_stages),), jnp.float32)

    with mesh:
        aparams_s = _sds_with(_ns(mesh, pspecs), aparams)
        abatch_s = _sds_with(_ns(mesh, bspecs), abatch)
        if shape.mode == "train":
            aopt = jax.eval_shape(opt_lib.init_opt_state, aparams)
            ospecs = sharding.param_specs(aopt)
            aopt_s = _sds_with(_ns(mesh, ospecs), aopt)
            step_fn = make_train_step(cfg, AdamWConfig(), microbatches=microbatches)
            # donate (params, opt): params'/opt' alias their inputs
            return jax.jit(step_fn, donate_argnums=(0, 1)).lower(
                aparams_s, aopt_s, abatch_s
            )
        if shape.mode == "prefill":
            step_fn = make_prefill_step(cfg, max_len=shape.seq_len)
            return jax.jit(step_fn).lower(aparams_s, abatch_s, thresholds)
        # decode
        acaches = model_lib.cache_specs(cfg, shape.global_batch, shape.seq_len)
        cspecs = sharding.cache_specs(acaches)
        acaches_s = _sds_with(_ns(mesh, cspecs), acaches)
        step_fn = make_decode_step(cfg)
        # donate the KV/state caches: in-place update halves the HBM bill
        return jax.jit(step_fn, donate_argnums=(2,)).lower(
            aparams_s, abatch_s, acaches_s, thresholds
        )


def _cost_dict(compiled) -> dict:
    """compiled.cost_analysis() across the jax return-type change (older
    versions hand back a one-element list of dicts, newer a plain dict)."""
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def _compile_costs(cfg, shape, mesh, microbatches: int = 1, policy: str = "dp_tp"):
    """compile; returns (per_device_flops, per_device_bytes, coll_stats)."""
    num_devices = int(np.prod(list(mesh.shape.values())))
    lowered = build_lowered(cfg, shape, mesh, microbatches, policy)
    compiled = lowered.compile()
    cost = _cost_dict(compiled)
    coll = collective_stats(compiled.as_text(), num_devices)
    return (
        float(cost.get("flops", 0.0)),
        float(cost.get("bytes accessed", 0.0)),
        coll,
    )


def _reduced_depth(cfg, k: int):
    return dataclasses.replace(cfg, num_layers=k * len(cfg.period) * cfg.num_stages)


def gate_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool,
    microbatches: int = 1,
    policy: str = "dp_tp",
):
    """Full-depth production compile — the runnability gate."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    num_devices = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()
    lowered = build_lowered(cfg, shape, mesh, microbatches, policy)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower
    try:
        mem = compiled.memory_analysis()
        mem_info = {
            "argument_size_gb": getattr(mem, "argument_size_in_bytes", 0) / 1e9,
            "output_size_gb": getattr(mem, "output_size_in_bytes", 0) / 1e9,
            "temp_size_gb": getattr(mem, "temp_size_in_bytes", 0) / 1e9,
            "peak_gb_per_device": (
                getattr(mem, "argument_size_in_bytes", 0)
                + getattr(mem, "temp_size_in_bytes", 0)
            )
            / 1e9,
            # The CPU backend ignores donate_argnums; on TPU the donated
            # cache/params+opt alias their outputs, so the output-sized
            # buffer (and its temp copy) disappears from the peak.
            "peak_gb_per_device_tpu": max(
                getattr(mem, "argument_size_in_bytes", 0)
                + getattr(mem, "temp_size_in_bytes", 0)
                - getattr(mem, "output_size_in_bytes", 0),
                getattr(mem, "argument_size_in_bytes", 0),
            )
            / 1e9,
        }
    except Exception as e:
        mem_info = {"error": str(e)}
    cost = _cost_dict(compiled)
    coll = collective_stats(compiled.as_text(), num_devices)
    return {
        "gate": "ok",
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": mem_info,
        "gate_collective_counts": coll.counts,
        "gate_flops_per_device_loopbody1": cost.get("flops", 0.0),
    }


def measure_cell(arch: str, shape_name: str, multi_pod: bool, policy: str = "dp_tp"):
    """Unrolled 2-point depth fit -> exact roofline terms at production depth."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    num_devices = int(np.prod(list(mesh.shape.values())))

    layers_lib.set_unroll(True)
    try:
        costs = {}
        for k in (1, 2):
            costs[k] = _compile_costs(_reduced_depth(cfg, k), shape, mesh, policy=policy)
    finally:
        layers_lib.set_unroll(False)

    periods = {k: k * cfg.num_stages for k in (1, 2)}
    p_target = cfg.num_periods

    def fit(v1: float, v2: float) -> float:
        a = (v2 - v1) / (periods[2] - periods[1])
        b = v1 - a * periods[1]
        return b + a * p_target

    flops_dev = fit(costs[1][0], costs[2][0])
    bytes_dev = fit(costs[1][1], costs[2][1])
    coll_dev = fit(costs[1][2].per_device_bytes, costs[2][2].per_device_bytes)
    by_op = {
        op: fit(costs[1][2].by_op.get(op, 0.0), costs[2][2].by_op.get(op, 0.0))
        for op in set(costs[1][2].by_op) | set(costs[2][2].by_op)
    }
    counts = {
        op: int(
            fit(costs[1][2].counts.get(op, 0), costs[2][2].counts.get(op, 0))
        )
        for op in set(costs[1][2].counts) | set(costs[2][2].counts)
    }

    # analytic correction for the sLSTM time recurrence (global numbers)
    extra_flops, extra_bytes = corrections.slstm_missing_cost(cfg, shape)

    from repro.roofline import constants
    from repro.roofline.hlo import CollectiveStats

    coll = CollectiveStats(
        per_device_bytes=coll_dev,
        global_bytes=coll_dev * num_devices,
        by_op=by_op,
        counts=counts,
    )
    flops_global = flops_dev * num_devices + extra_flops
    bytes_global = bytes_dev * num_devices + extra_bytes
    report = analysis.RooflineReport(
        arch=arch,
        shape=shape_name,
        mesh=mesh_name,
        num_devices=num_devices,
        hlo_flops=flops_global,
        hlo_bytes=bytes_global,
        collective=coll,
        model_flops=analysis.model_flops_for(cfg, shape),
        compute_s=flops_global / (num_devices * constants.PEAK_FLOPS_BF16),
        memory_s=bytes_global / (num_devices * constants.HBM_BW),
        collective_s=coll.global_bytes / (num_devices * constants.ICI_BW),
    )
    row = report.row()
    row["collective_by_op_gb"] = {k: v * num_devices / 1e9 for k, v in by_op.items()}
    row["collective_counts"] = counts
    row["slstm_correction_gflops"] = extra_flops / 1e9
    return row


def run_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool,
    fit: bool = True,
    gate: bool = True,
    microbatches: int = 1,
    save: bool = True,
    policy: str = "dp_tp",
    tag: str = "",
):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cell = f"{arch}__{shape_name}__{mesh_name}" + (f"__{tag}" if tag else "")
    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        return {"cell": cell, "skipped": reason}
    row = {"cell": cell, "arch": arch, "shape": shape_name, "mesh": mesh_name}
    path = os.path.join(ARTIFACT_DIR, cell + ".json")
    if os.path.exists(path):  # merge into an existing artifact (re-gate etc.)
        try:
            with open(path) as f:
                row = {**json.load(f), **row}
        except (OSError, json.JSONDecodeError):
            pass
    if gate:
        row.update(gate_cell(arch, shape_name, multi_pod, microbatches, policy))
    if fit:
        row.update(measure_cell(arch, shape_name, multi_pod, policy))
    if save:
        os.makedirs(ARTIFACT_DIR, exist_ok=True)
        with open(path, "w") as f:
            json.dump(row, f, indent=1)
    return row


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--no-fit", action="store_true", help="gate only")
    ap.add_argument("--no-gate", action="store_true", help="fit only")
    ap.add_argument("--policy", default="dp_tp", help="dp_tp | pure_dp")
    ap.add_argument("--tag", default="", help="artifact suffix for variants")
    args = ap.parse_args()

    archs = list_archs() if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = []
    for arch in archs:
        for shape_name in shapes:
            for mp in meshes:
                try:
                    row = run_cell(
                        arch,
                        shape_name,
                        mp,
                        fit=not args.no_fit,
                        gate=not args.no_gate,
                        microbatches=args.microbatches,
                        policy=args.policy,
                        tag=args.tag,
                    )
                except Exception:
                    failures.append((arch, shape_name, mp))
                    print(f"FAIL {arch} {shape_name} multi_pod={mp}", flush=True)
                    traceback.print_exc()
                    continue
                if "skipped" in row:
                    print(f"SKIP {row['cell']}: {row['skipped']}", flush=True)
                elif "dominant" in row:
                    print(
                        f"OK   {row['cell']}: dominant={row['dominant']} "
                        f"compute={row['compute_ms']:.2f}ms "
                        f"memory={row['memory_ms']:.2f}ms "
                        f"collective={row['collective_ms']:.2f}ms "
                        f"useful={row['useful_ratio']:.2f} "
                        f"roofline={row['roofline_fraction']:.3f}",
                        flush=True,
                    )
                else:
                    print(
                        f"OK   {row['cell']}: gate compile {row.get('compile_s')}s "
                        f"mem/dev {row['memory'].get('peak_gb_per_device', '?')}",
                        flush=True,
                    )
    if failures:
        raise SystemExit(f"{len(failures)} cells failed: {failures}")


if __name__ == "__main__":
    main()
