"""xlstm-350m [ssm] — alternating mLSTM / sLSTM blocks.

[arXiv:2405.04517; unverified]  24L d_model=1024 4H d_ff=0 vocab=50304.
xLSTM blocks carry their own up/down projections (d_ff=0: no separate FFN).
Pure recurrent state -> long_500k runs (O(1) state per decode step).
"""
from repro.configs.base import ArchConfig
from repro.models.ssm import XlstmDims

CONFIG = ArchConfig(
    name="xlstm-350m",
    family="ssm",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    norm="rmsnorm",
    act="silu",
    period=("mlstm", "slstm"),
    xlstm=XlstmDims(d_model=1024, num_heads=4, expand=2, chunk=256),
    num_stages=4,
    exit_stages=(2, 3),
    sub_quadratic=True,
)
