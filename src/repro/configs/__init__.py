"""Architecture configs: one module per assigned architecture + paper profiles."""
from repro.configs.base import ArchConfig, SHAPES, ShapeSpec, input_specs
from repro.configs.registry import get_config, list_archs

__all__ = ["ArchConfig", "SHAPES", "ShapeSpec", "input_specs", "get_config", "list_archs"]
