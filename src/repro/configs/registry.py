"""Architecture registry: ``--arch <id>`` -> ArchConfig.

Modules are imported lazily so that importing the registry never pulls in
every architecture's dependencies.
"""
from __future__ import annotations

import importlib

from repro.configs.base import ArchConfig

# arch id -> module holding CONFIG
_MODULES: dict[str, str] = {
    "phi-3-vision-4.2b": "repro.configs.phi_3_vision_4_2b",
    "zamba2-2.7b": "repro.configs.zamba2_2_7b",
    "internlm2-20b": "repro.configs.internlm2_20b",
    "qwen2.5-32b": "repro.configs.qwen2_5_32b",
    "glm4-9b": "repro.configs.glm4_9b",
    "stablelm-1.6b": "repro.configs.stablelm_1_6b",
    "mixtral-8x7b": "repro.configs.mixtral_8x7b",
    "deepseek-v2-lite-16b": "repro.configs.deepseek_v2_lite_16b",
    "musicgen-medium": "repro.configs.musicgen_medium",
    "xlstm-350m": "repro.configs.xlstm_350m",
}

_cache: dict[str, ArchConfig] = {}


def list_archs() -> list[str]:
    return list(_MODULES.keys())


def get_config(name: str) -> ArchConfig:
    if name not in _MODULES:
        raise KeyError(
            f"unknown architecture {name!r}; known: {', '.join(_MODULES)}"
        )
    if name not in _cache:
        mod = importlib.import_module(_MODULES[name])
        _cache[name] = mod.CONFIG
    return _cache[name]
