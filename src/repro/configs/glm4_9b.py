"""glm4-9b [dense] — RoPE + aggressive GQA (kv=2).  [hf:THUDM/glm-4-9b; hf]

40L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=151552.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="glm4-9b",
    family="dense",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=2,
    d_ff=13696,
    vocab_size=151552,
    head_dim=128,
    norm="rmsnorm",
    act="silu",
    qkv_bias=True,
    rope_theta=1e4,
    period=("attn",),
    num_stages=4,
    exit_stages=(2, 3),
    sub_quadratic=False,
)
