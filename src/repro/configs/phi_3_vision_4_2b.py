"""phi-3-vision-4.2b [vlm] — phi3-mini backbone + CLIP frontend (stubbed).

[hf:microsoft/Phi-3-vision-128k-instruct; hf]  32L d_model=3072 32H (GQA
kv=32 == MHA) d_ff=8192 vocab=32064.  The vision tower is a modality
frontend STUB: ``input_specs()`` hands the backbone precomputed patch
embeddings of shape [B, S, d_model] (assignment rules).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32064,
    norm="rmsnorm",
    act="silu",
    ffn="glu",
    rope_theta=1e4,
    period=("attn",),
    frontend="embeds",
    num_stages=4,
    exit_stages=(2, 3),
    sub_quadratic=False,  # pure full attention -> long_500k skipped
    notes="vision frontend stubbed as precomputed patch embeddings",
)
