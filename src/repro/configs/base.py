"""ArchConfig — the single description every subsystem consumes.

A model is a cycled ``period`` of block kinds (e.g. ``("attn",)`` for a
dense transformer, ``("mamba",)*5 + ("dense_attn",)`` for zamba2,
``("mlstm", "slstm")`` for xLSTM), partitioned into ``num_stages``
pipeline stages at period granularity.  Early-exit heads sit after the
stages named in ``exit_stages`` (1-indexed), mirroring the paper's
sub-model/branch layout.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.attention import AttnDims, MlaDims
from repro.models.moe import MoeDims
from repro.models.ssm import MambaDims, XlstmDims

BLOCK_KINDS = ("attn", "moe_attn", "mamba", "dense_attn", "mlstm", "slstm")


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads
    norm: str = "rmsnorm"
    act: str = "silu"
    ffn: str = "glu"  # "glu" (SwiGLU-style) | "mlp" (classic 2-matmul)
    qkv_bias: bool = False
    rope_theta: float = 1e4
    sliding_window: int | None = None
    period: tuple[str, ...] = ("attn",)
    moe: MoeDims | None = None
    mla: MlaDims | None = None
    mamba: MambaDims | None = None
    xlstm: XlstmDims | None = None
    frontend: str = "tokens"  # "tokens" | "embeds" (vlm/audio stub)
    num_stages: int = 4
    exit_stages: tuple[int, ...] = (2, 3)
    exit_loss_weight: float = 0.3
    sub_quadratic: bool = False  # can run long_500k
    q_chunk: int = 1024
    dtype: Any = jnp.bfloat16
    notes: str = ""

    # ---------------------------------------------------------------------
    def __post_init__(self) -> None:
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // max(self.num_heads, 1))
        for kind in self.period:
            if kind not in BLOCK_KINDS:
                raise ValueError(f"unknown block kind {kind!r}")
        if self.num_layers % len(self.period) != 0:
            raise ValueError(
                f"{self.name}: num_layers={self.num_layers} not divisible by "
                f"period length {len(self.period)}"
            )
        bad = [h for h in self.exit_stages if not (1 <= h < self.num_stages)]
        if bad:
            raise ValueError(f"exit stages {bad} out of range 1..{self.num_stages - 1}")

    # -- derived ------------------------------------------------------------
    @property
    def num_periods(self) -> int:
        return self.num_layers // len(self.period)

    def stage_periods(self) -> list[int]:
        """Periods per stage (near-even split, earlier stages get extras)."""
        return [len(a) for a in np.array_split(np.arange(self.num_periods), self.num_stages)]

    def attn_dims(self) -> AttnDims:
        return AttnDims(
            d_model=self.d_model,
            num_heads=self.num_heads,
            num_kv_heads=self.num_kv_heads,
            head_dim=self.head_dim,
            qkv_bias=self.qkv_bias,
            rope_theta=self.rope_theta,
            sliding_window=self.sliding_window,
        )

    @property
    def uses_attention(self) -> bool:
        return any(k in ("attn", "moe_attn", "dense_attn") for k in self.period)

    # -- parameter counts (roofline: MODEL_FLOPS = 6 N D) --------------------
    def param_count(self, active_only: bool = False) -> int:
        from repro.models import model as model_lib

        return model_lib.count_params(self, active_only=active_only)

    def reduced(self, **overrides) -> "ArchConfig":
        """A smoke-test-sized sibling: same family/period structure, tiny dims."""
        period = self.period
        n_periods = max(self.num_stages, 4)
        small: dict[str, Any] = dict(
            num_layers=n_periods * len(period),
            d_model=128,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 4) if self.num_kv_heads else 4,
            d_ff=256 if self.d_ff else 0,
            vocab_size=512,
            head_dim=32,
            sliding_window=32 if self.sliding_window else None,
            q_chunk=64,
        )
        if self.moe is not None:
            small["moe"] = dataclasses.replace(
                self.moe,
                d_model=128,
                d_ff_expert=64,
                num_experts=min(self.moe.num_experts, 8),
                d_ff_shared=64 if self.moe.num_shared else 0,
                top_k=min(self.moe.top_k, 2),
            )
        if self.mla is not None:
            small["mla"] = MlaDims(
                d_model=128,
                num_heads=4,
                kv_lora_rank=32,
                qk_nope_head_dim=32,
                qk_rope_head_dim=16,
                v_head_dim=32,
            )
            small["head_dim"] = 32
        if self.mamba is not None:
            small["mamba"] = dataclasses.replace(
                self.mamba, d_model=128, d_state=16, head_dim=32, chunk=16
            )
        if self.xlstm is not None:
            small["xlstm"] = dataclasses.replace(self.xlstm, d_model=128, num_heads=4, chunk=16)
        small.update(overrides)
        return dataclasses.replace(self, name=f"{self.name}-smoke", **small)


# ---------------------------------------------------------------------------
# Assigned input shapes (seq_len, global_batch, mode)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    mode: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ArchConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """(runnable, reason-if-skipped) per the assignment's skip rules."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, (
            "pure full-attention arch: a 500k dense-KV decode needs sub-quadratic "
            "attention (see DESIGN.md §4)"
        )
    return True, ""


def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    No device allocation happens here; the dry-run lowers against these.
    For ``decode`` the cache structs are produced separately by
    ``model.cache_specs`` (they are inputs of serve_step, not of the batch).
    """
    B, S = shape.global_batch, shape.seq_len
    f = jax.ShapeDtypeStruct
    if shape.mode == "train":
        if cfg.frontend == "embeds":
            return {
                "embeds": f((B, S, cfg.d_model), jnp.bfloat16),
                "labels": f((B, S), jnp.int32),
            }
        return {"tokens": f((B, S), jnp.int32), "labels": f((B, S), jnp.int32)}
    if shape.mode == "prefill":
        if cfg.frontend == "embeds":
            return {"embeds": f((B, S, cfg.d_model), jnp.bfloat16)}
        return {"tokens": f((B, S), jnp.int32)}
    if shape.mode == "decode":
        if cfg.frontend == "embeds":
            return {"embeds": f((B, 1, cfg.d_model), jnp.bfloat16)}
        return {"tokens": f((B, 1), jnp.int32)}
    raise ValueError(shape.mode)
