"""mixtral-8x7b [moe] — 8 experts top-2, sliding-window attention.

[arXiv:2401.04088; hf]  32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000.  SWA window 4096 -> decode touches only the window ring
buffer, so long_500k runs (O(n*w) attention).
"""
from repro.configs.base import ArchConfig
from repro.models.moe import MoeDims

CONFIG = ArchConfig(
    name="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    head_dim=128,
    norm="rmsnorm",
    act="silu",
    rope_theta=1e6,
    sliding_window=4096,
    period=("moe_attn",),
    moe=MoeDims(
        d_model=4096,
        d_ff_expert=14336,
        num_experts=8,
        top_k=2,
        router_norm="topk_softmax",
    ),
    num_stages=4,
    exit_stages=(2, 3),
    sub_quadratic=True,
)
