"""musicgen-medium [audio] — decoder-only over EnCodec tokens.

[arXiv:2306.05284; hf]  48L d_model=1536 24H (kv=24 == MHA) d_ff=6144
vocab=2048.  Classic post-GPT block: LayerNorm + 2-matmul GELU MLP.  The
EnCodec frontend is a STUB: ``input_specs()`` provides precomputed frame
embeddings.  H=5 stages mirrors the paper's BERT 5-way split.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-medium",
    family="audio",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    head_dim=64,
    norm="layernorm",
    act="gelu",
    ffn="mlp",
    rope_theta=1e4,
    period=("attn",),
    frontend="embeds",
    num_stages=5,
    exit_stages=(2, 3, 4),
    sub_quadratic=False,
    notes="EnCodec frontend stubbed as precomputed frame embeddings",
)
