"""zamba2-2.7b [hybrid] — Mamba2 backbone with shared attention blocks.

[arXiv:2411.15242; hf]  54L d_model=2560 32H (kv=32) d_ff=10240 vocab=32000,
ssm_state=64.  Layout: 5 Mamba2 blocks then one dense attention+FFN block,
repeated (the paper's "shared attention" inserted every ~6 blocks); 54 = 9
periods of 6.  Hybrid family -> runs long_500k (decode cost per step is
dominated by the SSM state; attention touches the KV cache linearly).
"""
from repro.configs.base import ArchConfig
from repro.models.ssm import MambaDims

CONFIG = ArchConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    norm="rmsnorm",
    act="silu",
    period=("mamba", "mamba", "mamba", "mamba", "mamba", "dense_attn"),
    mamba=MambaDims(d_model=2560, d_state=64, expand=2, head_dim=64, chunk=256),
    num_stages=4,
    exit_stages=(2, 3),
    sub_quadratic=True,
    notes="Mamba2 + periodic shared attn; SSM state cache carries long context",
)
