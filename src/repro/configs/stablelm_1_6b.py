"""stablelm-1.6b [dense].  [hf:stabilityai/stablelm-2-1_6b; unverified]

24L d_model=2048 32H (GQA kv=32 == MHA) d_ff=5632 vocab=100352.
LayerNorm + QKV bias per the stablelm-2 family.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-1.6b",
    family="dense",
    num_layers=24,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=5632,
    vocab_size=100352,
    head_dim=64,
    norm="layernorm",
    act="silu",
    qkv_bias=True,
    rope_theta=1e4,
    period=("attn",),
    num_stages=4,
    exit_stages=(2, 3),
    sub_quadratic=False,
)
