"""deepseek-v2-lite-16b [moe] — MLA + fine-grained MoE.

[arXiv:2405.04434; hf]  27L d_model=2048 16H (MLA kv_lora=512) expert
d_ff=1408 vocab=102400; 64 routed experts top-6 + 2 shared experts.
(The HF checkpoint keeps layer 0 dense; we model all 27 layers as MoE —
noted in DESIGN.md §Arch-applicability.)  MLA is compressed-KV but still a
full softmax over the cache -> long_500k skipped per the assignment rule.
"""
from repro.configs.base import ArchConfig
from repro.models.attention import MlaDims
from repro.models.moe import MoeDims

CONFIG = ArchConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=102400,
    head_dim=128,
    norm="rmsnorm",
    act="silu",
    rope_theta=1e4,
    period=("moe_attn",),
    mla=MlaDims(
        d_model=2048,
        num_heads=16,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    moe=MoeDims(
        d_model=2048,
        d_ff_expert=1408,
        num_experts=64,
        top_k=6,
        num_shared=2,
        router_norm="softmax_topk",
    ),
    num_stages=4,
    exit_stages=(2, 3),
    sub_quadratic=False,
)
