"""Pure-JAX model zoo: staged decoders with early-exit heads.

Layers are written as *global math* — sharding is applied through logical-axis
annotations (see repro.sharding) and GSPMD propagation, never per-shard code.
"""
