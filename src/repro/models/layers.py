"""Shared primitive layers: norms, embeddings, RoPE, FFNs.

Conventions:
  * params are nested dicts of jnp arrays; leaf names drive sharding rules
    (see repro.sharding.specs.param_spec).
  * every ``apply``-style function takes activations in compute dtype
    (bf16 by default) while params stay in param dtype (f32 master copies);
    casting happens at the matmul boundary.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

Params = dict[str, Any]


def truncated_normal_init(key, shape, scale: float, dtype=jnp.float32):
    """He-style truncated normal, stddev = scale / sqrt(fan_in)."""
    fan_in = shape[0] if len(shape) >= 2 else max(shape[-1], 1)
    std = scale / np.sqrt(fan_in)
    return std * jax.random.truncated_normal(key, -3.0, 3.0, shape, dtype)


def dense_init(key, d_in: int, d_out: int, *, scale: float = 1.0, dtype=jnp.float32):
    return truncated_normal_init(key, (d_in, d_out), scale, dtype)


def matmul(x: jnp.ndarray, w: jnp.ndarray, *, compute_dtype=jnp.bfloat16) -> jnp.ndarray:
    """x @ w with both operands cast to the compute dtype (MXU-friendly)."""
    return jnp.matmul(x.astype(compute_dtype), w.astype(compute_dtype))


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm_init(d: int) -> Params:
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(params: Params, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    """RMSNorm in f32 for stability, output back in x.dtype."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * params["scale"]
    return y.astype(dtype)


def layernorm_init(d: int) -> Params:
    return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}


def layernorm(params: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mean), axis=-1, keepdims=True)
    y = (x - mean) * jax.lax.rsqrt(var + eps) * params["scale"] + params["bias"]
    return y.astype(dtype)


def norm_init(kind: str, d: int) -> Params:
    if kind == "rmsnorm":
        return rmsnorm_init(d)
    if kind == "layernorm":
        return layernorm_init(d)
    raise ValueError(f"unknown norm {kind!r}")


def apply_norm(kind: str, params: Params, x: jnp.ndarray) -> jnp.ndarray:
    return rmsnorm(params, x) if kind == "rmsnorm" else layernorm(params, x)


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------

_ACTS = {
    "silu": jax.nn.silu,
    "gelu": jax.nn.gelu,
    "relu": jax.nn.relu,
}


def activation(name: str):
    return _ACTS[name]


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    """Inverse frequencies for even head dims (f32, [head_dim // 2])."""
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta**exponent)


def apply_rope(
    x: jnp.ndarray,  # [..., seq, heads, head_dim]
    positions: jnp.ndarray,  # [..., seq]
    theta: float = 1e4,
) -> jnp.ndarray:
    """Standard rotate-half RoPE over the last dim, position-indexed."""
    head_dim = x.shape[-1]
    inv = rope_frequencies(head_dim, theta)
    angles = positions[..., :, None].astype(jnp.float32) * inv  # [..., seq, hd/2]
    cos = jnp.cos(angles)[..., :, None, :]  # broadcast over heads
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Embeddings
# ---------------------------------------------------------------------------


def embedding_init(key, vocab: int, d: int) -> Params:
    return {"embed": truncated_normal_init(key, (vocab, d), 1.0)}


def embed(params: Params, tokens: jnp.ndarray, compute_dtype=jnp.bfloat16) -> jnp.ndarray:
    return params["embed"].astype(compute_dtype)[tokens]


# ---------------------------------------------------------------------------
# Feed-forward blocks
# ---------------------------------------------------------------------------


def glu_ffn_init(key, d: int, d_ff: int) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, d, d_ff),
        "w_up": dense_init(k2, d, d_ff),
        "w_down": dense_init(k3, d_ff, d),
    }


def glu_ffn(params: Params, x: jnp.ndarray, act: str = "silu") -> jnp.ndarray:
    """Gated FFN (SwiGLU et al.): down(act(gate(x)) * up(x))."""
    g = activation(act)(matmul(x, params["w_gate"]))
    u = matmul(x, params["w_up"])
    return matmul(g * u, params["w_down"])


def mlp_ffn_init(key, d: int, d_ff: int) -> Params:
    k1, k2 = jax.random.split(key, 2)
    return {"w_up": dense_init(k1, d, d_ff), "w_down": dense_init(k2, d_ff, d)}


def mlp_ffn(params: Params, x: jnp.ndarray, act: str = "gelu") -> jnp.ndarray:
    return matmul(activation(act)(matmul(x, params["w_up"])), params["w_down"])


# ---------------------------------------------------------------------------
# Loop strategy: scan (compact HLO) vs unrolled (exact cost_analysis)
# ---------------------------------------------------------------------------
# XLA's HLO cost analysis visits a while-loop body ONCE regardless of trip
# count, so the roofline measurement path unrolls every counted loop.  The
# production path keeps lax.scan/map (small HLO, fast compiles).  sLSTM's
# per-timestep recurrence is excluded (trip count == seq_len) and corrected
# analytically in repro.roofline.corrections.

UNROLL_LOOPS = False


def set_unroll(flag: bool) -> None:
    global UNROLL_LOOPS
    UNROLL_LOOPS = bool(flag)


def loop_map(fn, xs):
    """lax.map, or an unrolled python loop when UNROLL_LOOPS is set."""
    if not UNROLL_LOOPS:
        return jax.lax.map(fn, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    ys = [fn(jax.tree.map(lambda a: a[i], xs)) for i in range(n)]
    return jax.tree.map(lambda *leaves: jnp.stack(leaves), *ys)


def loop_scan(body, carry, xs, length: int | None = None):
    """lax.scan, or an unrolled python loop when UNROLL_LOOPS is set."""
    if not UNROLL_LOOPS:
        return jax.lax.scan(body, carry, xs, length=length)
    n = length if xs is None else jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        x_i = None if xs is None else jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, x_i)
        ys.append(y)
    if all(y is None for y in ys):
        return carry, None
    return carry, jax.tree.map(lambda *leaves: jnp.stack(leaves), *ys)
