"""State-space & recurrent blocks: Mamba2 (SSD) and xLSTM (mLSTM / sLSTM).

TPU adaptation notes (DESIGN.md §2):
  * Mamba2 runs the **chunked SSD algorithm** — quadratic *within* a chunk
    (pure matmuls on the MXU), linear scan *across* chunk states.  The
    sequential token-by-token recurrence exists only for decode.
  * mLSTM uses the same chunkwise decomposition with log-space
    stabilization (exponential gates), so training never materializes a
    per-timestep matrix memory; only S/Q chunk states are kept.
  * sLSTM is inherently sequential (h_{t-1} feeds the gates) — lax.scan
    over time; its state is O(B*H*P), small enough to checkpoint densely.

All cores are validated against sequential references in tests.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers
from repro.models.layers import Params, matmul


def _pick_chunk(seq_len: int, chunk: int) -> int:
    if seq_len % chunk == 0:
        return chunk
    # largest divisor of seq_len not exceeding requested chunk
    for c in range(min(chunk, seq_len), 0, -1):
        if seq_len % c == 0:
            return c
    return seq_len


def segsum(a: jnp.ndarray) -> jnp.ndarray:
    """Lower-triangular pairwise cumulative sums: out[..., t, s] = sum_{u=s+1..t} a[..., u].

    Entries with s > t are -inf (used as log-decays).
    """
    T = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]  # sum_{u=s+1..t}
    mask = jnp.tril(jnp.ones((T, T), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


# ---------------------------------------------------------------------------
# Causal depthwise conv1d (+ decode cache)
# ---------------------------------------------------------------------------


def causal_conv1d(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """x: [B,S,C], w: [K,C] depthwise, left-padded causal."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(K):  # K is 4 — unrolled adds beat a conv op on TPU here
        out = out + xp[:, i : i + x.shape[1], :] * w[i][None, None, :].astype(x.dtype)
    return out + b[None, None, :].astype(x.dtype)


def conv_step(x_t: jnp.ndarray, conv_cache: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray):
    """One decode step: x_t [B,C]; conv_cache [B,K-1,C] holds prior inputs."""
    K = w.shape[0]
    window = jnp.concatenate([conv_cache, x_t[:, None, :]], axis=1)  # [B,K,C]
    out = jnp.einsum("bkc,kc->bc", window, w.astype(x_t.dtype)) + b.astype(x_t.dtype)
    return out, window[:, 1:]


# ---------------------------------------------------------------------------
# Mamba2 (SSD)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MambaDims:
    d_model: int
    d_state: int = 64
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    conv_kernel: int = 4
    chunk: int = 256

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def num_heads(self) -> int:
        assert self.d_inner % self.head_dim == 0
        return self.d_inner // self.head_dim

    @property
    def conv_dim(self) -> int:
        return self.d_inner + 2 * self.n_groups * self.d_state

    @property
    def in_proj_dim(self) -> int:
        # [z, x, B, C, dt]
        return 2 * self.d_inner + 2 * self.n_groups * self.d_state + self.num_heads


def mamba_init(key, dims: MambaDims) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    H = dims.num_heads
    return {
        "in_proj": layers.dense_init(k1, dims.d_model, dims.in_proj_dim),
        "conv_w": layers.truncated_normal_init(k2, (dims.conv_kernel, dims.conv_dim), 1.0),
        "conv_b": jnp.zeros((dims.conv_dim,), jnp.float32),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, H)),  # A = -exp(a_log)
        "d_skip": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((H,), 0.01))),  # softplus^-1(0.01)
        "norm": layers.rmsnorm_init(dims.d_inner),
        "out_proj": layers.dense_init(k3, dims.d_inner, dims.d_model),
    }


def _mamba_split(params: Params, x: jnp.ndarray, dims: MambaDims):
    proj = matmul(x, params["in_proj"])
    di, gn = dims.d_inner, dims.n_groups * dims.d_state
    z = proj[..., :di]
    xbc = proj[..., di : di + dims.conv_dim]
    dt = proj[..., di + dims.conv_dim :]
    return z, xbc, dt


def ssd_chunked(
    x: jnp.ndarray,  # [B,S,H,P]
    a: jnp.ndarray,  # [B,S,H]  log-decay per step (= dt * A, negative)
    b: jnp.ndarray,  # [B,S,G,N]
    c: jnp.ndarray,  # [B,S,G,N]
    chunk: int,
    initial_state: jnp.ndarray | None = None,  # [B,H,P,N]
):
    """Chunked SSD scan (Mamba2).  Returns (y [B,S,H,P], final_state)."""
    B, S, H, P = x.shape
    G, N = b.shape[2], b.shape[3]
    Q = _pick_chunk(S, chunk)
    nC = S // Q
    hpg = H // G  # heads per group

    xr = x.reshape(B, nC, Q, H, P)
    ar = a.reshape(B, nC, Q, H).astype(jnp.float32)
    br = b.reshape(B, nC, Q, G, N)
    cr = c.reshape(B, nC, Q, G, N)

    a_cum = jnp.cumsum(ar, axis=2)  # [B,nC,Q,H]

    # ---- intra-chunk (quadratic, matmul-heavy) ---------------------------
    L = jnp.exp(segsum(ar.transpose(0, 1, 3, 2)))  # [B,nC,H,Q,Q]
    cb = jnp.einsum("bcqgn,bcsgn->bcgqs", cr.astype(jnp.float32), br.astype(jnp.float32))
    cb = jnp.repeat(cb, hpg, axis=2)  # [B,nC,H,Q,S] group -> heads
    scores = (cb * L).astype(x.dtype)
    y_diag = jnp.einsum("bchqs,bcshp->bcqhp", scores, xr)

    # ---- chunk boundary states -------------------------------------------
    decay_to_end = jnp.exp(a_cum[:, :, -1:, :] - a_cum)  # [B,nC,Q,H]
    bx = jnp.einsum(
        "bcqgn,bcqh,bcqhp->bchpn",
        br.astype(jnp.float32),
        decay_to_end,
        xr.astype(jnp.float32),
    )  # per-chunk state contribution

    chunk_decay = jnp.exp(a_cum[:, :, -1, :])  # [B,nC,H] total decay of chunk

    def scan_fn(h_prev, inputs):
        bx_c, dec_c = inputs  # [B,H,P,N], [B,H]
        h_new = h_prev * dec_c[..., None, None] + bx_c
        return h_new, h_prev

    h0 = (
        initial_state.astype(jnp.float32)
        if initial_state is not None
        else jnp.zeros((B, H, P, N), jnp.float32)
    )
    h_final, h_prevs = layers.loop_scan(
        scan_fn,
        h0,
        (bx.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)  # [B,nC,H,P,N] state entering chunk

    # ---- inter-chunk output ----------------------------------------------
    state_decay = jnp.exp(a_cum)  # decay from chunk start to step q
    c_heads = jnp.repeat(cr, hpg, axis=3 - 1) if G != H else cr
    c_full = jnp.repeat(cr.astype(jnp.float32), hpg, axis=3)  # [B,nC,Q,H,N]
    y_off = jnp.einsum("bcqhn,bchpn,bcqh->bcqhp", c_full, h_prevs, state_decay)

    y = (y_diag.astype(jnp.float32) + y_off).reshape(B, S, H, P)
    return y.astype(x.dtype), h_final


def ssd_step(
    x_t: jnp.ndarray,  # [B,H,P]
    a_t: jnp.ndarray,  # [B,H]
    b_t: jnp.ndarray,  # [B,G,N]
    c_t: jnp.ndarray,  # [B,G,N]
    state: jnp.ndarray,  # [B,H,P,N] f32
):
    """Single-token SSD recurrence (decode)."""
    H = x_t.shape[1]
    G = b_t.shape[1]
    hpg = H // G
    b_full = jnp.repeat(b_t, hpg, axis=1).astype(jnp.float32)  # [B,H,N]
    c_full = jnp.repeat(c_t, hpg, axis=1).astype(jnp.float32)
    decay = jnp.exp(a_t.astype(jnp.float32))[..., None, None]
    new_state = state * decay + jnp.einsum(
        "bhp,bhn->bhpn", x_t.astype(jnp.float32), b_full
    )
    y = jnp.einsum("bhpn,bhn->bhp", new_state, c_full)
    return y.astype(x_t.dtype), new_state


def mamba_forward(
    params: Params,
    x: jnp.ndarray,  # [B,S,d]
    dims: MambaDims,
    initial_state: jnp.ndarray | None = None,
    return_state: bool = False,
):
    B, S, _ = x.shape
    H, P, N, G = dims.num_heads, dims.head_dim, dims.d_state, dims.n_groups
    z, xbc, dt_raw = _mamba_split(params, x, dims)
    xbc = jax.nn.silu(causal_conv1d(xbc, params["conv_w"], params["conv_b"]))
    xs = xbc[..., : dims.d_inner].reshape(B, S, H, P)
    b = xbc[..., dims.d_inner : dims.d_inner + G * N].reshape(B, S, G, N)
    c = xbc[..., dims.d_inner + G * N :].reshape(B, S, G, N)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])  # [B,S,H]
    a = -jnp.exp(params["a_log"])[None, None, :] * dt  # log decay, negative

    y, state = ssd_chunked(xs * dt[..., None].astype(xs.dtype), a, b, c, dims.chunk, initial_state)
    y = y + xs * params["d_skip"][None, None, :, None].astype(y.dtype)
    y = y.reshape(B, S, dims.d_inner)
    y = layers.rmsnorm(params["norm"], y * jax.nn.silu(z))
    out = matmul(y, params["out_proj"])
    if return_state:
        return out, state
    return out


def make_mamba_cache(batch: int, dims: MambaDims, dtype=jnp.bfloat16) -> Params:
    return {
        "conv": jnp.zeros((batch, dims.conv_kernel - 1, dims.conv_dim), dtype),
        "ssd": jnp.zeros((batch, dims.num_heads, dims.head_dim, dims.d_state), jnp.float32),
        "pos": jnp.zeros((), jnp.int32),
    }


def mamba_decode(params: Params, x: jnp.ndarray, cache: Params, dims: MambaDims):
    """x: [B,1,d] -> (out [B,1,d], cache')."""
    B = x.shape[0]
    H, P, N, G = dims.num_heads, dims.head_dim, dims.d_state, dims.n_groups
    z, xbc, dt_raw = _mamba_split(params, x[:, 0], dims)
    xbc, conv_new = conv_step(xbc, cache["conv"], params["conv_w"], params["conv_b"])
    xbc = jax.nn.silu(xbc)
    xs = xbc[..., : dims.d_inner].reshape(B, H, P)
    b = xbc[..., dims.d_inner : dims.d_inner + G * N].reshape(B, G, N)
    c = xbc[..., dims.d_inner + G * N :].reshape(B, G, N)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])  # [B,H]
    a = -jnp.exp(params["a_log"])[None, :] * dt
    y, ssd_new = ssd_step(xs * dt[..., None].astype(xs.dtype), a, b, c, cache["ssd"])
    y = y + xs * params["d_skip"][None, :, None].astype(y.dtype)
    y = layers.rmsnorm(params["norm"], y.reshape(B, dims.d_inner) * jax.nn.silu(z))
    out = matmul(y, params["out_proj"])[:, None, :]
    return out, {"conv": conv_new, "ssd": ssd_new, "pos": cache["pos"] + 1}


# ---------------------------------------------------------------------------
# mLSTM (xLSTM matrix-memory cell), chunkwise-parallel
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class XlstmDims:
    d_model: int
    num_heads: int
    expand: int = 2  # mLSTM inner expansion
    conv_kernel: int = 4
    chunk: int = 256
    slstm_proj_factor: float = 4.0 / 3.0

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def m_head_dim(self) -> int:
        assert self.d_inner % self.num_heads == 0
        return self.d_inner // self.num_heads

    @property
    def s_head_dim(self) -> int:
        assert self.d_model % self.num_heads == 0
        return self.d_model // self.num_heads

    @property
    def slstm_ff(self) -> int:
        f = int(self.d_model * self.slstm_proj_factor)
        return ((f + 63) // 64) * 64  # 64-align for the MXU


def mlstm_init(key, dims: XlstmDims) -> Params:
    ks = jax.random.split(key, 7)
    di = dims.d_inner
    H = dims.num_heads
    return {
        "up_proj": layers.dense_init(ks[0], dims.d_model, 2 * di),  # [x | z-gate]
        "conv_w": layers.truncated_normal_init(ks[1], (dims.conv_kernel, di), 1.0),
        "conv_b": jnp.zeros((di,), jnp.float32),
        "w_q": layers.dense_init(ks[2], di, di),
        "w_k": layers.dense_init(ks[3], di, di),
        "w_v": layers.dense_init(ks[4], di, di),
        "w_if": layers.dense_init(ks[5], di, 2 * H),  # input & forget gate logits
        "if_bias": jnp.concatenate([jnp.zeros((H,)), 3.0 * jnp.ones((H,))]),
        "norm_h": layers.rmsnorm_init(di),
        "down_proj": layers.dense_init(ks[6], di, dims.d_model),
    }


def mlstm_chunked(
    q: jnp.ndarray,  # [B,S,H,P] (already scaled by 1/sqrt(P))
    k: jnp.ndarray,  # [B,S,H,P]
    v: jnp.ndarray,  # [B,S,H,P]
    i_gate: jnp.ndarray,  # [B,S,H]  raw input-gate logits (exp gate)
    f_gate: jnp.ndarray,  # [B,S,H]  raw forget-gate logits (sigmoid in log space)
    chunk: int,
    initial: tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray] | None = None,
):
    """Stabilized chunkwise mLSTM.  Returns (h [B,S,H,P], (C, n, m) final).

    State convention: stored (C_hat, n_hat) are the true values scaled by
    exp(-m); m is the running log-stabilizer per (B, H).
    """
    B, S, H, P = q.shape
    Q = _pick_chunk(S, chunk)
    nC = S // Q

    qr = q.reshape(B, nC, Q, H, P)
    kr = k.reshape(B, nC, Q, H, P)
    vr = v.reshape(B, nC, Q, H, P)
    ir = i_gate.reshape(B, nC, Q, H).astype(jnp.float32)
    lf = jax.nn.log_sigmoid(f_gate.reshape(B, nC, Q, H).astype(jnp.float32))

    F = jnp.cumsum(lf, axis=2)  # [B,nC,Q,H] inclusive cumsum of log-forgets
    F_total = F[:, :, -1, :]  # [B,nC,H]

    # log-weights of intra-chunk source s for target t:  F_t - F_s + i_s
    D = (F[:, :, :, None, :] - F[:, :, None, :, :]) + ir[:, :, None, :, :]
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    D = jnp.where(tri[None, None, :, :, None], D, -jnp.inf)  # [B,nC,t,s,H]
    intra_max = jnp.max(D, axis=3)  # [B,nC,Q,H]

    # log-weight of state contribution at step t: F_t (+ m_prev, added in scan)
    # per-chunk scan carries (C_hat, n_hat, m) and emits per-chunk h.
    def scan_fn(carry, inp):
        C_hat, n_hat, m = carry  # [B,H,P,P], [B,H,P], [B,H]
        qc, kc, vc, Dc, imaxc, Fc, Ftotc, irc = inp
        # new stabilizer per step: max(intra max, F_t + m_prev)
        m_t = jnp.maximum(imaxc, Fc + m[:, None, :])  # [B,Q,H]
        w_intra = jnp.exp(Dc - m_t[:, :, None, :])  # [B,t,s,H]
        scores = jnp.einsum("bthp,bshp->btsh", qc.astype(jnp.float32), kc.astype(jnp.float32))
        sw = scores * w_intra
        num_intra = jnp.einsum("btsh,bshp->bthp", sw, vc.astype(jnp.float32))
        den_intra = jnp.sum(sw, axis=2)  # [B,t,H]

        w_state = jnp.exp(Fc + m[:, None, :] - m_t)  # [B,Q,H]
        num_state = jnp.einsum("bthp,bhpn->bthn", qc.astype(jnp.float32), C_hat)
        num_state = num_state * w_state[..., None]
        den_state = jnp.einsum("bthp,bhp->bth", qc.astype(jnp.float32), n_hat) * w_state

        num = num_intra + num_state
        den = den_intra + den_state
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]

        # ---- end-of-chunk state update ------------------------------------
        lw_src = Ftotc[:, None, :] - Fc + irc  # [B,Q,H] log-weight of source s
        m_new = jnp.maximum(Ftotc + m, jnp.max(lw_src, axis=1))  # [B,H]
        w_src = jnp.exp(lw_src - m_new[:, None, :])  # [B,Q,H]
        C_new = C_hat * jnp.exp(Ftotc + m - m_new)[..., None, None] + jnp.einsum(
            "bshp,bsh,bshn->bhpn", vc.astype(jnp.float32), w_src, kc.astype(jnp.float32)
        )
        n_new = n_hat * jnp.exp(Ftotc + m - m_new)[..., None] + jnp.einsum(
            "bsh,bshp->bhp", w_src, kc.astype(jnp.float32)
        )
        return (C_new, n_new, m_new), h

    if initial is None:
        C0 = jnp.zeros((B, H, P, P), jnp.float32)
        n0 = jnp.zeros((B, H, P), jnp.float32)
        m0 = jnp.full((B, H), -1e30, jnp.float32)  # empty state has weight 0
    else:
        C0, n0, m0 = initial

    xs = (
        qr.transpose(1, 0, 2, 3, 4),
        kr.transpose(1, 0, 2, 3, 4),
        vr.transpose(1, 0, 2, 3, 4),
        D.transpose(1, 0, 2, 3, 4),
        intra_max.transpose(1, 0, 2, 3),
        F.transpose(1, 0, 2, 3),
        F_total.transpose(1, 0, 2),
        ir.transpose(1, 0, 2, 3),
    )
    (Cf, nf, mf), hs = layers.loop_scan(scan_fn, (C0, n0, m0), xs)
    h = hs.transpose(1, 0, 2, 3, 4).reshape(B, S, H, P)
    return h.astype(q.dtype), (Cf, nf, mf)


def mlstm_step(
    q: jnp.ndarray,  # [B,H,P] scaled
    k: jnp.ndarray,
    v: jnp.ndarray,
    i_gate: jnp.ndarray,  # [B,H]
    f_gate: jnp.ndarray,  # [B,H]
    state: tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray],
):
    C_hat, n_hat, m = state
    lf = jax.nn.log_sigmoid(f_gate.astype(jnp.float32))
    i = i_gate.astype(jnp.float32)
    m_new = jnp.maximum(lf + m, i)
    f_w = jnp.exp(lf + m - m_new)
    i_w = jnp.exp(i - m_new)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    C_new = C_hat * f_w[..., None, None] + i_w[..., None, None] * jnp.einsum(
        "bhp,bhn->bhpn", vf, kf
    )
    n_new = n_hat * f_w[..., None] + i_w[..., None] * kf
    qf = q.astype(jnp.float32)
    num = jnp.einsum("bhp,bhpn->bhn", qf, C_new)
    den = jnp.einsum("bhp,bhp->bh", qf, n_new)
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
    return h.astype(q.dtype), (C_new, n_new, m_new)


def mlstm_forward(
    params: Params,
    x: jnp.ndarray,
    dims: XlstmDims,
    initial: tuple | None = None,
    return_state: bool = False,
):
    B, S, _ = x.shape
    H, P = dims.num_heads, dims.m_head_dim
    up = matmul(x, params["up_proj"])
    xi, z = jnp.split(up, 2, axis=-1)
    conv_out = jax.nn.silu(causal_conv1d(xi, params["conv_w"], params["conv_b"]))
    q = matmul(conv_out, params["w_q"]).reshape(B, S, H, P) / np.sqrt(P)
    k = matmul(conv_out, params["w_k"]).reshape(B, S, H, P)
    v = matmul(xi, params["w_v"]).reshape(B, S, H, P)
    gates = matmul(xi, params["w_if"]).astype(jnp.float32) + params["if_bias"]
    i_gate, f_gate = jnp.split(gates, 2, axis=-1)
    h, state = mlstm_chunked(q, k, v, i_gate, f_gate, dims.chunk, initial)
    h = h.reshape(B, S, dims.d_inner)
    h = layers.rmsnorm(params["norm_h"], h) * jax.nn.silu(z)
    out = matmul(h, params["down_proj"])
    if return_state:
        return out, state
    return out


def make_mlstm_cache(batch: int, dims: XlstmDims) -> Params:
    H, P = dims.num_heads, dims.m_head_dim
    return {
        "conv": jnp.zeros((batch, dims.conv_kernel - 1, dims.d_inner), jnp.bfloat16),
        "C": jnp.zeros((batch, H, P, P), jnp.float32),
        "n": jnp.zeros((batch, H, P), jnp.float32),
        "m": jnp.full((batch, H), -1e30, jnp.float32),
        "pos": jnp.zeros((), jnp.int32),
    }


def mlstm_decode(params: Params, x: jnp.ndarray, cache: Params, dims: XlstmDims):
    B = x.shape[0]
    H, P = dims.num_heads, dims.m_head_dim
    up = matmul(x[:, 0], params["up_proj"])
    xi, z = jnp.split(up, 2, axis=-1)
    conv_out, conv_new = conv_step(xi, cache["conv"], params["conv_w"], params["conv_b"])
    conv_out = jax.nn.silu(conv_out)
    q = matmul(conv_out, params["w_q"]).reshape(B, H, P) / np.sqrt(P)
    k = matmul(conv_out, params["w_k"]).reshape(B, H, P)
    v = matmul(xi, params["w_v"]).reshape(B, H, P)
    gates = matmul(xi, params["w_if"]).astype(jnp.float32) + params["if_bias"]
    i_gate, f_gate = jnp.split(gates, 2, axis=-1)
    h, (C, n, m) = mlstm_step(q, k, v, i_gate, f_gate, (cache["C"], cache["n"], cache["m"]))
    h = layers.rmsnorm(params["norm_h"], h.reshape(B, dims.d_inner)) * jax.nn.silu(z)
    out = matmul(h, params["down_proj"])[:, None, :]
    return out, {"conv": conv_new, "C": C, "n": n, "m": m, "pos": cache["pos"] + 1}


# ---------------------------------------------------------------------------
# sLSTM (scalar-memory cell) — sequential by construction
# ---------------------------------------------------------------------------


def slstm_init(key, dims: XlstmDims) -> Params:
    ks = jax.random.split(key, 4)
    d, H, P = dims.d_model, dims.num_heads, dims.s_head_dim
    return {
        "w_gates": layers.dense_init(ks[0], d, 4 * d),  # z, i, f, o pre-activations
        "r_gates": layers.truncated_normal_init(ks[1], (H, P, 4 * P), 1.0),  # block-diag recurrent
        "gate_bias": jnp.zeros((4 * d,), jnp.float32),
        "norm_h": layers.rmsnorm_init(d),
        "ffn": layers.glu_ffn_init(ks[2], d, dims.slstm_ff),
    }


def slstm_cell(
    w_x: jnp.ndarray,  # [B, 4d] input pre-activations for this step
    r_gates: jnp.ndarray,  # [H, P, 4P]
    gate_bias: jnp.ndarray,
    state: tuple,  # (c, n, h, m) each [B,H,P]
    H: int,
    P: int,
):
    c, n, h, m = state
    B = w_x.shape[0]
    rec = jnp.einsum("bhp,hpq->bhq", h, r_gates.astype(h.dtype))  # [B,H,4P]
    pre = w_x.reshape(B, H, 4 * P).astype(jnp.float32) + rec.astype(jnp.float32)
    pre = pre + gate_bias.reshape(H, 4 * P)[None]
    z_p, i_p, f_p, o_p = jnp.split(pre, 4, axis=-1)  # each [B,H,P]
    z = jnp.tanh(z_p)
    o = jax.nn.sigmoid(o_p)
    lf = jax.nn.log_sigmoid(f_p)
    m_new = jnp.maximum(lf + m, i_p)
    i_w = jnp.exp(i_p - m_new)
    f_w = jnp.exp(lf + m - m_new)
    c_new = f_w * c + i_w * z
    n_new = f_w * n + i_w
    h_new = o * c_new / jnp.maximum(n_new, 1.0)
    return (c_new, n_new, h_new, m_new), h_new


def slstm_forward(
    params: Params,
    x: jnp.ndarray,
    dims: XlstmDims,
    initial: tuple | None = None,
    return_state: bool = False,
):
    B, S, d = x.shape
    H, P = dims.num_heads, dims.s_head_dim
    w_x = matmul(x, params["w_gates"])  # [B,S,4d]

    if initial is None:
        zeros = jnp.zeros((B, H, P), jnp.float32)
        initial = (zeros, zeros, zeros, jnp.full((B, H, P), -1e30, jnp.float32))

    def step(state, w_t):
        return slstm_cell(w_t, params["r_gates"], params["gate_bias"], state, H, P)

    state, hs = jax.lax.scan(step, initial, w_x.transpose(1, 0, 2))
    h = hs.transpose(1, 0, 2, 3).reshape(B, S, d).astype(x.dtype)
    h = layers.rmsnorm(params["norm_h"], h)
    out = h + layers.glu_ffn(params["ffn"], h)
    if return_state:
        return out, state
    return out


def make_slstm_cache(batch: int, dims: XlstmDims) -> Params:
    H, P = dims.num_heads, dims.s_head_dim
    zeros = jnp.zeros((batch, H, P), jnp.float32)
    return {
        "c": zeros,
        "n": zeros,
        "h": zeros,
        "m": jnp.full((batch, H, P), -1e30, jnp.float32),
        "pos": jnp.zeros((), jnp.int32),
    }


def slstm_decode(params: Params, x: jnp.ndarray, cache: Params, dims: XlstmDims):
    B = x.shape[0]
    H, P = dims.num_heads, dims.s_head_dim
    w_x = matmul(x[:, 0], params["w_gates"])
    state = (cache["c"], cache["n"], cache["h"], cache["m"])
    (c, n, h_s, m), h = slstm_cell(w_x, params["r_gates"], params["gate_bias"], state, H, P)
    hh = layers.rmsnorm(params["norm_h"], h.reshape(B, -1).astype(x.dtype))
    out = hh + layers.glu_ffn(params["ffn"], hh)
    return out[:, None, :], {"c": c, "n": n, "h": h_s, "m": m, "pos": cache["pos"] + 1}
