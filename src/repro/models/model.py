"""Staged decoder with early-exit heads — the data plane the paper's
control plane (DTO-EE) schedules.

A model is ``num_stages`` pipeline stages; each stage scans over repeated
block *periods* (see ArchConfig.period).  Early-exit branches (paper: b_h)
hang off the stages in ``cfg.exit_stages``: RMSNorm + the shared LM head;
confidence = top-1 softmax probability, exactly what DTO-EE thresholds.

Three entry points per architecture:
  * loss_fn        — training forward with deep supervision over exits
  * prefill        — full-sequence forward that also builds decode caches
  * decode_step    — one token against the caches, returning per-exit
                     (confidence, argmax) so the serving engine can apply
                     the paper's thresholds C
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import attention, layers, moe, ssm
from repro.models.layers import Params
from repro.sharding import constrain

# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------


def _block_init(key, kind: str, cfg: ArchConfig) -> Params:
    d = cfg.d_model
    if kind in ("attn", "dense_attn", "moe_attn"):
        ka, kf = jax.random.split(key)
        attn_p = (
            attention.mla_init(ka, cfg.mla)
            if cfg.mla is not None
            else attention.gqa_init(ka, cfg.attn_dims())
        )
        p: Params = {
            "norm1": layers.norm_init(cfg.norm, d),
            "attn": attn_p,
            "norm2": layers.norm_init(cfg.norm, d),
        }
        if kind == "moe_attn":
            p["moe"] = moe.moe_init(kf, cfg.moe)
        elif cfg.ffn == "mlp":
            p["ffn"] = layers.mlp_ffn_init(kf, d, cfg.d_ff)
        else:
            p["ffn"] = layers.glu_ffn_init(kf, d, cfg.d_ff)
        return p
    if kind == "mamba":
        return {"norm": layers.norm_init(cfg.norm, d), "mamba": ssm.mamba_init(key, cfg.mamba)}
    if kind == "mlstm":
        return {"norm": layers.norm_init(cfg.norm, d), "mlstm": ssm.mlstm_init(key, cfg.xlstm)}
    if kind == "slstm":
        return {"norm": layers.norm_init(cfg.norm, d), "slstm": ssm.slstm_init(key, cfg.xlstm)}
    raise ValueError(kind)


def init_params(key, cfg: ArchConfig) -> Params:
    keys = jax.random.split(key, 3 + cfg.num_periods)
    params: Params = {}
    if cfg.frontend == "tokens":
        params["embed"] = layers.embedding_init(keys[0], cfg.vocab_size, cfg.d_model)
    params["lm_head"] = layers.dense_init(keys[1], cfg.d_model, cfg.vocab_size)
    params["final_norm"] = layers.norm_init(cfg.norm, cfg.d_model)
    params["exit_norms"] = {
        f"exit_{h}": layers.norm_init(cfg.norm, cfg.d_model) for h in cfg.exit_stages
    }

    stages = []
    period_keys = iter(keys[3:])
    for n_periods in cfg.stage_periods():
        stage_key = next(period_keys)
        blocks = []
        for i, kind in enumerate(cfg.period):
            pk = jax.random.fold_in(stage_key, i)
            stacked = jax.vmap(lambda k: _block_init(k, kind, cfg))(
                jax.random.split(pk, n_periods)
            )
            blocks.append(stacked)
        stages.append({"blocks": tuple(blocks)})
    params["stages"] = stages
    return params


def abstract_params(cfg: ArchConfig) -> Params:
    """ShapeDtypeStruct pytree — no allocation (dry-run path)."""
    return jax.eval_shape(lambda: init_params(jax.random.key(0), cfg))


def count_params(cfg: ArchConfig, active_only: bool = False) -> int:
    p = abstract_params(cfg)
    total = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(p))
    if active_only and cfg.moe is not None:
        n_moe = sum(1 for k in cfg.period if k == "moe_attn") * cfg.num_periods
        inactive_per_block = (
            (cfg.moe.num_experts - cfg.moe.top_k) * 3 * cfg.moe.d_model * cfg.moe.d_ff_expert
        )
        total -= n_moe * inactive_per_block
    return total


# ---------------------------------------------------------------------------
# Cache construction
# ---------------------------------------------------------------------------


def _block_cache(kind: str, cfg: ArchConfig, batch: int, max_len: int) -> Params | None:
    if kind in ("attn", "dense_attn", "moe_attn"):
        if cfg.mla is not None:
            return attention.make_mla_cache(batch, max_len, cfg.mla)
        dims = cfg.attn_dims()
        if dims.sliding_window is not None and dims.sliding_window < max_len:
            return attention.make_window_cache(batch, dims)
        return attention.make_kv_cache(batch, max_len, dims)
    if kind == "mamba":
        return ssm.make_mamba_cache(batch, cfg.mamba)
    if kind == "mlstm":
        return ssm.make_mlstm_cache(batch, cfg.xlstm)
    if kind == "slstm":
        return ssm.make_slstm_cache(batch, cfg.xlstm)
    raise ValueError(kind)


def init_caches(cfg: ArchConfig, batch: int, max_len: int) -> list:
    """Concrete zeroed caches, mirroring the stage/period/stack structure."""
    caches = []
    for n_periods in cfg.stage_periods():
        per_stage = []
        for kind in cfg.period:
            one = _block_cache(kind, cfg, batch, max_len)
            stacked = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (n_periods,) + a.shape).copy(), one
            )
            per_stage.append(stacked)
        caches.append(tuple(per_stage))
    return caches


def cache_specs(cfg: ArchConfig, batch: int, max_len: int):
    return jax.eval_shape(lambda: init_caches(cfg, batch, max_len))


# ---------------------------------------------------------------------------
# Block application (train / prefill)
# ---------------------------------------------------------------------------


def _cache_from_kv(k: jnp.ndarray, v: jnp.ndarray, window: int | None, max_len: int) -> Params:
    B, S = k.shape[0], k.shape[1]
    if window is not None and window < max_len:
        W = window
        cache = {
            "k": jnp.zeros((B, W) + k.shape[2:], jnp.bfloat16),
            "v": jnp.zeros((B, W) + v.shape[2:], jnp.bfloat16),
            "pos": jnp.asarray(S, jnp.int32),
            "slot_pos": jnp.full((W,), -1, jnp.int32),
        }
        n = min(S, W)
        start = S - n
        pos_tail = np.arange(0, n) + start  # static
        slots = pos_tail % W
        cache["k"] = cache["k"].at[:, slots].set(k[:, start:].astype(jnp.bfloat16))
        cache["v"] = cache["v"].at[:, slots].set(v[:, start:].astype(jnp.bfloat16))
        cache["slot_pos"] = cache["slot_pos"].at[slots].set(pos_tail.astype(np.int32))
        return cache
    cache = attention.make_kv_cache(B, max_len, _dims_from_kv(k))
    return attention.prefill_into_cache(cache, k, v)


def _dims_from_kv(k: jnp.ndarray) -> attention.AttnDims:
    # only shapes matter for make_kv_cache
    return attention.AttnDims(
        d_model=0, num_heads=k.shape[2], num_kv_heads=k.shape[2], head_dim=k.shape[3]
    )


def _block_apply(
    kind: str,
    p: Params,
    x: jnp.ndarray,
    cfg: ArchConfig,
    positions: jnp.ndarray,
    mode: str,  # "train" | "prefill"
    max_len: int = 0,
):
    """Returns (x', cache_or_None, aux_loss)."""
    build_cache = mode == "prefill"
    aux = jnp.zeros((), jnp.float32)
    if kind in ("attn", "dense_attn", "moe_attn"):
        h = layers.apply_norm(cfg.norm, p["norm1"], x)
        cache = None
        if cfg.mla is not None:
            if build_cache:
                out, (c_kv, k_pe) = attention.mla_forward(
                    p["attn"], h, cfg.mla, positions, cfg.q_chunk, return_latent=True
                )
                cache = attention.make_mla_cache(x.shape[0], max_len, cfg.mla)
                cache = attention.mla_prefill_into_cache(cache, c_kv, k_pe)
            else:
                out = attention.mla_forward(p["attn"], h, cfg.mla, positions, cfg.q_chunk)
        else:
            dims = cfg.attn_dims()
            if build_cache:
                out, (k, v) = attention.gqa_forward(
                    p["attn"], h, dims, positions, cfg.q_chunk, return_kv=True
                )
                cache = _cache_from_kv(k, v, dims.sliding_window, max_len)
            else:
                out = attention.gqa_forward(p["attn"], h, dims, positions, cfg.q_chunk)
        x = x + out
        h2 = layers.apply_norm(cfg.norm, p["norm2"], x)
        if kind == "moe_attn":
            ffn_out, aux = moe.moe_forward(p["moe"], h2, cfg.moe)
        elif cfg.ffn == "mlp":
            ffn_out = layers.mlp_ffn(p["ffn"], h2, cfg.act)
        else:
            ffn_out = layers.glu_ffn(p["ffn"], h2, cfg.act)
        return x + ffn_out, cache, aux

    h = layers.apply_norm(cfg.norm, p["norm"], x)
    if kind == "mamba":
        if build_cache:
            out, state = ssm.mamba_forward(p["mamba"], h, cfg.mamba, return_state=True)
            cache = ssm.make_mamba_cache(x.shape[0], cfg.mamba)
            cache = dict(cache, ssd=state, pos=jnp.asarray(x.shape[1], jnp.int32))
            # conv tail: last K-1 pre-conv features; recomputed cheaply
            _, xbc, _ = ssm._mamba_split(p["mamba"], h[:, -(cfg.mamba.conv_kernel - 1) :], cfg.mamba)
            cache["conv"] = xbc.astype(cache["conv"].dtype)
            return x + out, cache, aux
        out = ssm.mamba_forward(p["mamba"], h, cfg.mamba)
        return x + out, None, aux
    if kind == "mlstm":
        if build_cache:
            out, (C, n, m) = ssm.mlstm_forward(p["mlstm"], h, cfg.xlstm, return_state=True)
            cache = ssm.make_mlstm_cache(x.shape[0], cfg.xlstm)
            up = layers.matmul(
                h[:, -(cfg.xlstm.conv_kernel - 1) :], p["mlstm"]["up_proj"]
            )
            cache = dict(
                cache,
                C=C,
                n=n,
                m=m,
                conv=jnp.split(up, 2, axis=-1)[0].astype(cache["conv"].dtype),
                pos=jnp.asarray(x.shape[1], jnp.int32),
            )
            return x + out, cache, aux
        out = ssm.mlstm_forward(p["mlstm"], h, cfg.xlstm)
        return x + out, None, aux
    if kind == "slstm":
        # sLSTM block output includes its own residual & FFN (xLSTM block form)
        if build_cache:
            out, (c, n, hs, m) = ssm.slstm_forward(p["slstm"], h, cfg.xlstm, return_state=True)
            cache = ssm.make_slstm_cache(x.shape[0], cfg.xlstm)
            cache = dict(cache, c=c, n=n, h=hs, m=m, pos=jnp.asarray(x.shape[1], jnp.int32))
            return x + out, cache, aux
        out = ssm.slstm_forward(p["slstm"], h, cfg.xlstm)
        return x + out, None, aux
    raise ValueError(kind)


def _block_decode(
    kind: str,
    p: Params,
    x: jnp.ndarray,
    cache: Params,
    cfg: ArchConfig,
    ragged: bool = False,
    paged_seq_len: int | None = None,
):
    """One-token block step.  ``ragged=True`` treats ``cache["pos"]`` as a
    per-row int32 [B] vector (the serving engine's slot-cache batches mix
    requests at different prefix lengths); SSM state steps are position-free,
    so only the attention variants branch.  ``paged_seq_len`` selects the
    paged-attention path: the cache carries a physical block pool plus a
    per-row block ``table`` instead of contiguous rows (still ragged)."""
    if kind in ("attn", "dense_attn", "moe_attn"):
        h = layers.apply_norm(cfg.norm, p["norm1"], x)
        if paged_seq_len is not None:
            if cfg.mla is not None:
                out, cache = attention.mla_decode_paged(
                    p["attn"], h, cache, cfg.mla, paged_seq_len
                )
            else:
                out, cache = attention.gqa_decode_paged(
                    p["attn"], h, cache, cfg.attn_dims(), paged_seq_len
                )
        elif cfg.mla is not None:
            mla_fn = attention.mla_decode_ragged if ragged else attention.mla_decode
            out, cache = mla_fn(p["attn"], h, cache, cfg.mla)
        else:
            gqa_fn = attention.gqa_decode_ragged if ragged else attention.gqa_decode
            out, cache = gqa_fn(p["attn"], h, cache, cfg.attn_dims())
        x = x + out
        h2 = layers.apply_norm(cfg.norm, p["norm2"], x)
        if kind == "moe_attn":
            ffn_out, _ = moe.moe_forward(p["moe"], h2, cfg.moe)
        elif cfg.ffn == "mlp":
            ffn_out = layers.mlp_ffn(p["ffn"], h2, cfg.act)
        else:
            ffn_out = layers.glu_ffn(p["ffn"], h2, cfg.act)
        return x + ffn_out, cache
    h = layers.apply_norm(cfg.norm, p["norm"], x)
    if kind == "mamba":
        out, cache = ssm.mamba_decode(p["mamba"], h, cache, cfg.mamba)
    elif kind == "mlstm":
        out, cache = ssm.mlstm_decode(p["mlstm"], h, cache, cfg.xlstm)
    elif kind == "slstm":
        out, cache = ssm.slstm_decode(p["slstm"], h, cache, cfg.xlstm)
    else:
        raise ValueError(kind)
    return x + out, cache


# ---------------------------------------------------------------------------
# Stage runners
# ---------------------------------------------------------------------------


def _run_stage(
    stage: Params,
    x: jnp.ndarray,
    cfg: ArchConfig,
    positions: jnp.ndarray,
    mode: str,
    max_len: int = 0,
):
    """Scan over this stage's periods.  Returns (x, stacked_caches, aux)."""
    period = cfg.period

    def body(carry, per_params):
        x, aux = carry
        caches = []
        for i, kind in enumerate(period):
            x, cache, a = _block_apply(kind, per_params[i], x, cfg, positions, mode, max_len)
            caches.append(cache)
            aux = aux + a
        # REPRO_SP=0 drops the sequence-parallel residual constraint
        # (a §Perf knob: its backward reshards cotangents in f32)
        import os as _os

        if _os.environ.get("REPRO_SP", "1") == "1":
            x = constrain(x, "batch", "seq", None)
        else:
            x = constrain(x, "batch", None, None)
        ys = tuple(caches) if mode == "prefill" else None
        return (x, aux), ys

    body = jax.checkpoint(body)
    (x, aux), stage_caches = layers.loop_scan(
        body, (x, jnp.zeros((), jnp.float32)), stage["blocks"]
    )
    return x, stage_caches, aux


def _decode_stage(
    stage: Params,
    x: jnp.ndarray,
    caches,
    cfg: ArchConfig,
    ragged: bool = False,
    paged_seq_len: int | None = None,
):
    period = cfg.period

    def body(x, inp):
        per_params, per_cache = inp
        new_caches = []
        for i, kind in enumerate(period):
            x, nc = _block_decode(
                kind, per_params[i], x, per_cache[i], cfg, ragged, paged_seq_len
            )
            new_caches.append(nc)
        return x, tuple(new_caches)

    x, new_caches = layers.loop_scan(body, x, (stage["blocks"], caches))
    return x, new_caches


# ---------------------------------------------------------------------------
# Per-stage entry points (the collaborative serving data plane)
# ---------------------------------------------------------------------------
#
# ``prefill`` / ``decode_step`` below run all H stages monolithically; the
# serving engine instead hands the residual stream replica-to-replica, so it
# needs the SAME math split at stage granularity: one prefill that builds one
# stage's caches, one cached decode step against them, and a slot-resident
# cache layout whose batch rows belong to different requests.


def prefill_stage(
    params: Params, stage_idx: int, x: jnp.ndarray, cfg: ArchConfig, max_len: int
):
    """Prefill through stage ``stage_idx`` (1-indexed): residual stream in,
    (residual stream out, stage caches sized ``max_len``) back."""
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)
    out, caches, _ = _run_stage(
        params["stages"][stage_idx - 1], x, cfg, positions, "prefill", max_len
    )
    return out, caches


def decode_stage_ragged(
    params: Params, stage_idx: int, x: jnp.ndarray, caches, cfg: ArchConfig
):
    """One token through stage ``stage_idx`` against its caches, with
    per-row positions (``cache["pos"]``: int32 [B])."""
    return _decode_stage(params["stages"][stage_idx - 1], x, caches, cfg, ragged=True)


# cache leaves with a ``max_len`` sequence dimension — the only ones the
# paged layout moves into the block pool; everything else (per-slot SSM
# state, conv tails, positions) stays slot-indexed
PAGED_CACHE_LEAVES = ("k", "v", "c_kv", "k_pe")


def validate_slot_layout(cfg: ArchConfig, stage_idx: int, max_len: int) -> None:
    """Reject configs the slot-resident cache layouts cannot represent, up
    front and with an actionable message (not mid-tree-map)."""
    if cfg.uses_attention and cfg.mla is None:
        w = cfg.attn_dims().sliding_window
        if w is not None and w < max_len:
            raise ValueError(
                f"stage {stage_idx} of config {cfg.name!r}: slot-resident "
                f"caches need full attention caches, but sliding_window={w} "
                f"< max_len={max_len}. Serve with max_len <= sliding_window, "
                "set ArchConfig.sliding_window=None, or use the monolithic "
                "decode path; per-slot window rings are a ROADMAP item."
            )


def init_stage_slot_caches(cfg: ArchConfig, stage_idx: int, num_slots: int, max_len: int):
    """Zeroed slot-resident caches for one stage's replica (dense layout).

    Leaves are shaped ``[n_periods, num_slots, ...]`` with ``pos`` a per-slot
    int32 vector — each slot holds one request's stage-local cache row, so a
    decode batch can gather any subset of slots (continuous batching).
    Sliding-window ring caches are not representable per-slot yet.
    """
    validate_slot_layout(cfg, stage_idx, max_len)
    n_periods = cfg.stage_periods()[stage_idx - 1]
    per_stage = []
    for kind in cfg.period:
        one = _block_cache(kind, cfg, num_slots, max_len)
        one["pos"] = jnp.zeros((num_slots,), jnp.int32)
        stacked = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_periods,) + a.shape).copy(), one
        )
        per_stage.append(stacked)
    return tuple(per_stage)


def init_stage_paged_caches(
    cfg: ArchConfig,
    stage_idx: int,
    num_slots: int,
    num_blocks: int,
    block_size: int,
    max_len: int,
):
    """Zeroed PAGED caches for one stage's replica: ``(pool, state)``.

    ``pool`` holds the sequence-dimension leaves (``k``/``v`` or MLA
    ``c_kv``/``k_pe``) as physical block pools ``[n_periods, num_blocks,
    block_size, ...]`` addressed through per-request block tables; ``state``
    keeps everything per-slot (``pos`` plus any SSM state), shaped
    ``[n_periods, num_slots, ...]`` exactly like the dense layout.  Both
    counts INCLUDE their trailing trash row (padded batch rows write there).
    """
    validate_slot_layout(cfg, stage_idx, max_len)
    n_periods = cfg.stage_periods()[stage_idx - 1]

    def stack(d):
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_periods,) + a.shape).copy(), d
        )

    pool_stage, state_stage = [], []
    for kind in cfg.period:
        if kind in ("attn", "dense_attn", "moe_attn"):
            if cfg.mla is not None:
                one = attention.make_mla_cache(num_blocks, block_size, cfg.mla)
            else:
                one = attention.make_kv_cache(num_blocks, block_size, cfg.attn_dims())
            pool = {k: v for k, v in one.items() if k in PAGED_CACHE_LEAVES}
            state = {}
        else:
            pool = {}
            state = {
                k: v
                for k, v in _block_cache(kind, cfg, num_slots, max_len).items()
                if k != "pos"
            }
        state["pos"] = jnp.zeros((num_slots,), jnp.int32)
        pool_stage.append(stack(pool))
        state_stage.append(stack(state))
    return tuple(pool_stage), tuple(state_stage)


def decode_stage_paged(
    params: Params,
    stage_idx: int,
    x: jnp.ndarray,
    pool_caches,
    state_rows,
    tables: jnp.ndarray,  # int32 [B, n_logical]
    cfg: ArchConfig,
    seq_len: int,
):
    """One token through stage ``stage_idx`` reading/writing the block pool
    through per-row block tables.

    ``pool_caches``: per-period pool dicts ``[n_periods, num_blocks, bs, ...]``
    (updated in place, returned whole); ``state_rows``: the batch's gathered
    per-slot rows ``[n_periods, B, ...]`` including ``pos``.  Returns
    ``(x_out, new_caches)`` with each period's dict holding both the updated
    pools and the updated batch rows.
    """
    n_periods = cfg.stage_periods()[stage_idx - 1]
    caches = []
    for pool_d, state_d in zip(pool_caches, state_rows):
        c = dict(state_d)
        c.update(pool_d)
        if pool_d:  # attention kinds read through the table
            c["table"] = jnp.broadcast_to(
                tables[None], (n_periods,) + tables.shape
            )
        caches.append(c)
    return _decode_stage(
        params["stages"][stage_idx - 1],
        x,
        tuple(caches),
        cfg,
        ragged=True,
        paged_seq_len=seq_len,
    )


# ---------------------------------------------------------------------------
# Heads
# ---------------------------------------------------------------------------


def _head_matrix(params: Params, cfg: ArchConfig) -> jnp.ndarray:
    return params["lm_head"]


def lm_logits(params: Params, hidden: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    return layers.matmul(hidden, _head_matrix(params, cfg)).astype(jnp.float32)


def _head_confidence(params: Params, norm_params, hidden: jnp.ndarray, cfg: ArchConfig):
    """(confidence, argmax) of one LM-head branch on [B, 1, d] hidden states.

    Routed through kernels.ops so the fused Pallas head is used on TPU —
    [B, vocab] logits are never materialized.
    """
    from repro.kernels import ops as kernel_ops

    h = layers.apply_norm(cfg.norm, norm_params, hidden[:, 0])
    return kernel_ops.exit_confidence(h, _head_matrix(params, cfg))


def exit_confidence(params: Params, hidden: jnp.ndarray, stage: int, cfg: ArchConfig):
    """(confidence, argmax) of exit branch b_h on [B, 1, d] hidden states."""
    return _head_confidence(params, params["exit_norms"][f"exit_{stage}"], hidden, cfg)


def final_confidence(params: Params, hidden: jnp.ndarray, cfg: ArchConfig):
    """(confidence, argmax) of the final head — the mandatory exit shares the
    early branches' fused path."""
    return _head_confidence(params, params["final_norm"], hidden, cfg)


def chunked_xent(
    hidden: jnp.ndarray,  # [B, S, d]
    labels: jnp.ndarray,  # [B, S] (-1 == masked)
    head: jnp.ndarray,  # [d, V]
    chunk: int = 512,
):
    """Mean token NLL without materializing [B, S, V] logits."""
    B, S, d = hidden.shape
    chunk = min(chunk, S)
    if S % chunk != 0:
        chunk = S
    nC = S // chunk
    hc = hidden.reshape(B, nC, chunk, d).swapaxes(0, 1)
    yc = labels.reshape(B, nC, chunk).swapaxes(0, 1)

    def one(args):
        h, y = args
        logits = layers.matmul(h, head).astype(jnp.float32)  # [B, C, V]
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(logits, jnp.maximum(y, 0)[..., None], axis=-1)[..., 0]
        mask = (y >= 0).astype(jnp.float32)
        return jnp.sum((lse - tgt) * mask), jnp.sum(mask)

    nll, cnt = layers.loop_map(one, (hc, yc))
    return jnp.sum(nll), jnp.sum(cnt)


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------


def _embed_inputs(params: Params, batch: dict, cfg: ArchConfig) -> jnp.ndarray:
    if cfg.frontend == "embeds":
        x = batch["embeds"].astype(cfg.dtype)
    else:
        x = layers.embed(params["embed"], batch["tokens"], cfg.dtype)
    return constrain(x, "batch", "seq", None)


def forward_hidden(params: Params, batch: dict, cfg: ArchConfig):
    """Full forward; returns (final_hidden, {stage: exit_hidden}, aux)."""
    x = _embed_inputs(params, batch, cfg)
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    exits: dict[int, jnp.ndarray] = {}
    aux_total = jnp.zeros((), jnp.float32)
    for si, stage in enumerate(params["stages"], start=1):
        x, _, aux = _run_stage(stage, x, cfg, positions, "train")
        aux_total = aux_total + aux
        if si in cfg.exit_stages:
            exits[si] = x
    return x, exits, aux_total


def loss_fn(params: Params, batch: dict, cfg: ArchConfig, aux_weight: float = 0.01):
    """Deep-supervision LM loss: final head + weighted early-exit heads."""
    x, exits, moe_aux = forward_hidden(params, batch, cfg)
    head = _head_matrix(params, cfg)
    labels = batch["labels"]

    h_final = layers.apply_norm(cfg.norm, params["final_norm"], x)
    nll, cnt = chunked_xent(h_final, labels, head)
    total = nll
    weight_sum = cnt
    per_exit = {}
    for h_stage in cfg.exit_stages:
        he = layers.apply_norm(
            cfg.norm, params["exit_norms"][f"exit_{h_stage}"], exits[h_stage]
        )
        e_nll, e_cnt = chunked_xent(he, labels, head)
        per_exit[f"exit_{h_stage}_loss"] = e_nll / jnp.maximum(e_cnt, 1.0)
        total = total + cfg.exit_loss_weight * e_nll
        weight_sum = weight_sum + cfg.exit_loss_weight * e_cnt

    loss = total / jnp.maximum(weight_sum, 1.0) + aux_weight * moe_aux
    metrics = {
        "loss": loss,
        "final_loss": nll / jnp.maximum(cnt, 1.0),
        "moe_aux": moe_aux,
        **per_exit,
    }
    return loss, metrics


def prefill(params: Params, batch: dict, cfg: ArchConfig, max_len: int):
    """Returns (next_token [B], exit_conf [B, n_exits], exit_token [B, n_exits],
    caches)."""
    x = _embed_inputs(params, batch, cfg)
    B, S = x.shape[0], x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    caches, confs, toks = [], [], []
    for si, stage in enumerate(params["stages"], start=1):
        x, stage_caches, _ = _run_stage(stage, x, cfg, positions, "prefill", max_len)
        caches.append(stage_caches)
        if si in cfg.exit_stages:
            c, t = exit_confidence(params, x[:, -1:], si, cfg)
            confs.append(c)
            toks.append(t)
    # final head through the same fused path as the exit branches: f32-
    # accumulated logits that never materialize [B, vocab]
    _, next_token = final_confidence(params, x[:, -1:], cfg)
    exit_conf = jnp.stack(confs, axis=1) if confs else jnp.zeros((B, 0), jnp.float32)
    exit_tok = jnp.stack(toks, axis=1) if toks else jnp.zeros((B, 0), jnp.int32)
    return next_token, exit_conf, exit_tok, caches


def decode_step(params: Params, batch: dict, caches: list, cfg: ArchConfig):
    """One token for every sequence; returns (next_token, exit_conf,
    exit_token, caches')."""
    x = _embed_inputs(params, batch, cfg)
    B = x.shape[0]
    new_caches, confs, toks = [], [], []
    for si, (stage, stage_cache) in enumerate(zip(params["stages"], caches), start=1):
        x, nc = _decode_stage(stage, x, stage_cache, cfg)
        new_caches.append(nc)
        if si in cfg.exit_stages:
            c, t = exit_confidence(params, x, si, cfg)
            confs.append(c)
            toks.append(t)
    _, next_token = final_confidence(params, x, cfg)
    exit_conf = jnp.stack(confs, axis=1) if confs else jnp.zeros((B, 0), jnp.float32)
    exit_tok = jnp.stack(toks, axis=1) if toks else jnp.zeros((B, 0), jnp.int32)
    return next_token, exit_conf, exit_tok, new_caches
