"""Mixture-of-Experts with capacity-based scatter dispatch (GShard-style).

Why scatter dispatch: computing every expert densely for every token costs
FLOPs proportional to E (4x waste for Mixtral top-2-of-8, ~10x for
DeepSeek's 64-expert router).  Dispatching tokens into per-expert capacity
buffers keeps the FLOP count proportional to top_k * capacity_factor —
which is what the 6*N_active*D roofline number assumes.

Dispatch uses scatter-add with within-expert ranks from a cumsum; tokens
whose rank exceeds the capacity are dropped (standard GShard semantics) by
routing them to a sacrificial extra slot.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers
from repro.models.layers import Params, matmul


@dataclasses.dataclass(frozen=True)
class MoeDims:
    d_model: int
    d_ff_expert: int
    num_experts: int
    top_k: int
    num_shared: int = 0
    d_ff_shared: int = 0  # total shared-expert hidden dim (0 => num_shared * d_ff_expert)
    capacity_factor: float = 1.25
    act: str = "silu"
    # "softmax_topk": softmax over all experts then take top-k (DeepSeek)
    # "topk_softmax": take top-k logits then softmax over them (Mixtral)
    router_norm: str = "topk_softmax"

    @property
    def shared_ff(self) -> int:
        if self.num_shared == 0:
            return 0
        return self.d_ff_shared or self.num_shared * self.d_ff_expert


def moe_init(key, dims: MoeDims) -> Params:
    kr, kg, ku, kd, ks = jax.random.split(key, 5)
    E, d, f = dims.num_experts, dims.d_model, dims.d_ff_expert
    p: Params = {
        "router": layers.dense_init(kr, d, E),
        "experts": {
            "w_gate": layers.truncated_normal_init(kg, (E, d, f), 1.0),
            "w_up": layers.truncated_normal_init(ku, (E, d, f), 1.0),
            "w_down": layers.truncated_normal_init(kd, (E, f, d), 1.0),
        },
    }
    if dims.num_shared > 0:
        p["shared"] = layers.glu_ffn_init(ks, d, dims.shared_ff)
    return p


def router_probs(logits: jnp.ndarray, dims: MoeDims):
    """Return (gates [T,k], expert_idx [T,k], probs_full [T,E])."""
    probs_full = jax.nn.softmax(logits, axis=-1)
    if dims.router_norm == "softmax_topk":
        gates, idx = jax.lax.top_k(probs_full, dims.top_k)
        gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)
    else:
        top_logits, idx = jax.lax.top_k(logits, dims.top_k)
        gates = jax.nn.softmax(top_logits, axis=-1)
    return gates, idx, probs_full


def capacity(num_tokens: int, dims: MoeDims) -> int:
    c = int(np.ceil(num_tokens * dims.top_k * dims.capacity_factor / dims.num_experts))
    return max(c, dims.top_k)


def moe_forward(params: Params, x: jnp.ndarray, dims: MoeDims):
    """x: [B, S, d]  ->  (out [B, S, d], aux_loss scalar).

    aux_loss is the switch-style load-balance loss E * sum_e f_e * P_e.
    """
    B, S, d = x.shape
    T = B * S
    E, k = dims.num_experts, dims.top_k
    C = capacity(T, dims)
    xf = x.reshape(T, d)

    logits = matmul(xf, params["router"]).astype(jnp.float32)  # [T, E]
    gates, idx, probs_full = router_probs(logits, dims)

    # ---- aux load-balance loss -------------------------------------------
    ones = jnp.zeros((T, E), jnp.float32).at[jnp.arange(T)[:, None], idx].add(1.0)
    f_e = ones.mean(axis=0) / k  # fraction routed to e
    p_e = probs_full.mean(axis=0)
    aux = E * jnp.sum(f_e * p_e)

    # ---- within-expert ranks via prefix sum over (token, k) choices ------
    # associative_scan = log-depth prefix sum: O(n log n) work on TPU (a
    # naive cumsum lowers via reduce-window, quadratic in XLA's cost model
    # and slow for the million-token dispatch tables MoE training builds)
    flat_e = idx.reshape(T * k)  # expert of each choice
    choice_onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)  # [T*k, E]
    scan_incl = jax.lax.associative_scan(jnp.add, choice_onehot, axis=0)
    ranks_all = scan_incl - choice_onehot
    rank = jnp.take_along_axis(ranks_all, flat_e[:, None], axis=1)[:, 0]  # [T*k]

    dropped = rank >= C
    slot = jnp.where(dropped, C, rank)  # C == sacrificial overflow slot

    flat_gate = gates.reshape(T * k)
    token_of_choice = jnp.repeat(jnp.arange(T), k)

    # ---- dispatch: scatter tokens into per-expert buffers ----------------
    buf = jnp.zeros((E, C + 1, d), xf.dtype)
    buf = buf.at[flat_e, slot].add(xf[token_of_choice])
    expert_in = buf[:, :C]  # [E, C, d]

    # ---- expert FFN (batched over experts) --------------------------------
    we = params["experts"]
    act = layers.activation(dims.act)
    g = act(jnp.einsum("ecd,edf->ecf", expert_in, we["w_gate"].astype(expert_in.dtype)))
    u = jnp.einsum("ecd,edf->ecf", expert_in, we["w_up"].astype(expert_in.dtype))
    expert_out = jnp.einsum("ecf,efd->ecd", g * u, we["w_down"].astype(expert_in.dtype))

    # ---- combine: gather back and weight by gates --------------------------
    padded = jnp.concatenate(
        [expert_out, jnp.zeros((E, 1, d), expert_out.dtype)], axis=1
    )  # overflow slot reads zeros
    picked = padded[flat_e, slot]  # [T*k, d]
    weighted = picked * flat_gate[:, None].astype(picked.dtype)
    out = jnp.sum(weighted.reshape(T, k, d), axis=1)

    if "shared" in params:
        out = out + layers.glu_ffn(params["shared"], xf, dims.act)

    return out.reshape(B, S, d), aux


def moe_active_params(dims: MoeDims) -> int:
    """Parameters touched per token (for 6*N_active*D roofline accounting)."""
    per_expert = 3 * dims.d_model * dims.d_ff_expert
    routed = dims.top_k * per_expert
    shared = 3 * dims.d_model * dims.shared_ff
    router = dims.d_model * dims.num_experts
    return routed + shared + router


def moe_total_params(dims: MoeDims) -> int:
    per_expert = 3 * dims.d_model * dims.d_ff_expert
    shared = 3 * dims.d_model * dims.shared_ff
    router = dims.d_model * dims.num_experts
    return dims.num_experts * per_expert + shared + router
