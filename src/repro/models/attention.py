"""Attention variants: GQA (optional bias / sliding window) and MLA
(DeepSeek-style multi-head latent attention), plus their KV caches.

Prefill/train attention is *q-chunked*: scores are materialized only for a
block of queries at a time (lax.map over chunks), so a 32k-token prefill
never builds an S x S score tensor.  The Pallas flash kernel
(repro.kernels.flash_attention) is the TPU-optimized drop-in for the same
math; this module is the XLA path the dry-run lowers.

Caches are plain dicts (pytrees):
  full   : {"k": [B,S,kv,hd], "v": [B,S,kv,hd], "pos": int32[]}
  window : same shapes with S == window; ring-buffer indexed by pos % window,
           plus "slot_pos": int32[window] holding each slot's global position
           (-1 == empty).
  mla    : {"c_kv": [B,S,lora], "k_pe": [B,S,rope_dim], "pos": int32[]}
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers
from repro.models.layers import Params, apply_rope, dense_init, matmul

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class AttnDims:
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    qkv_bias: bool = False
    rope_theta: float = 1e4
    sliding_window: int | None = None

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def groups(self) -> int:
        assert self.num_heads % self.num_kv_heads == 0
        return self.num_heads // self.num_kv_heads


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------


def gqa_init(key, dims: AttnDims) -> Params:
    kq, kk, kv, ko = jax.random.split(key, 4)
    p: Params = {
        "w_q": dense_init(kq, dims.d_model, dims.q_dim),
        "w_k": dense_init(kk, dims.d_model, dims.kv_dim),
        "w_v": dense_init(kv, dims.d_model, dims.kv_dim),
        "w_o": dense_init(ko, dims.q_dim, dims.d_model),
    }
    if dims.qkv_bias:
        p["b_q"] = jnp.zeros((dims.q_dim,), jnp.float32)
        p["b_k"] = jnp.zeros((dims.kv_dim,), jnp.float32)
        p["b_v"] = jnp.zeros((dims.kv_dim,), jnp.float32)
    return p


def _project_qkv(params: Params, x: jnp.ndarray, dims: AttnDims):
    B, S, _ = x.shape
    q = matmul(x, params["w_q"])
    k = matmul(x, params["w_k"])
    v = matmul(x, params["w_v"])
    if "b_q" in params:
        q = q + params["b_q"].astype(q.dtype)
        k = k + params["b_k"].astype(k.dtype)
        v = v + params["b_v"].astype(v.dtype)
    q = q.reshape(B, S, dims.num_heads, dims.head_dim)
    k = k.reshape(B, S, dims.num_kv_heads, dims.head_dim)
    v = v.reshape(B, S, dims.num_kv_heads, dims.head_dim)
    return q, k, v


def _attend_block(
    q: jnp.ndarray,  # [B, Cq, Hq, hd]
    k: jnp.ndarray,  # [B, Sk, kv, hd]
    v: jnp.ndarray,  # [B, Sk, kv, hd]
    q_pos: jnp.ndarray,  # [Cq] global positions of the queries
    k_pos: jnp.ndarray,  # [Sk] global positions of the keys (-1 == invalid)
    groups: int,
    window: int | None,
) -> jnp.ndarray:
    """Masked softmax attention for one q-chunk (grouped heads)."""
    B, Cq, Hq, hd = q.shape
    kvh = k.shape[2]
    qg = q.reshape(B, Cq, kvh, groups, hd)
    scale = 1.0 / np.sqrt(hd)
    # [B, kv, g, Cq, Sk]
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32) * scale
    causal = q_pos[:, None] >= k_pos[None, :]
    valid = k_pos[None, :] >= 0
    mask = causal & valid
    if window is not None:
        mask = mask & (k_pos[None, :] > q_pos[:, None] - window)
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
    return out.reshape(B, Cq, Hq, v.shape[-1])  # v head dim may differ (MLA)


def chunked_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    q_positions: jnp.ndarray,  # [Sq]
    k_positions: jnp.ndarray,  # [Sk]
    groups: int,
    window: int | None = None,
    q_chunk: int = 1024,
) -> jnp.ndarray:
    """Causal attention, q chunked so scores stay [B, kv, g, Cq, Sk]."""
    B, Sq, Hq, hd = q.shape
    q_chunk = min(q_chunk, Sq)
    if Sq % q_chunk != 0:  # fall back to one block for ragged tiny shapes
        q_chunk = Sq
    n_chunks = Sq // q_chunk
    qc = q.reshape(B, n_chunks, q_chunk, Hq, hd).swapaxes(0, 1)
    pc = q_positions.reshape(n_chunks, q_chunk)

    def one(args):
        qb, pb = args
        return _attend_block(qb, k, v, pb, k_positions, groups, window)

    out = layers.loop_map(one, (qc, pc))  # [n_chunks, B, q_chunk, Hq, v_hd]
    return out.swapaxes(0, 1).reshape(B, Sq, Hq, v.shape[-1])


def gqa_forward(
    params: Params,
    x: jnp.ndarray,  # [B, S, d]
    dims: AttnDims,
    positions: jnp.ndarray | None = None,  # [S]
    q_chunk: int = 1024,
    return_kv: bool = False,
):
    """Full-sequence causal attention (train / prefill)."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S, dtype=jnp.int32)
    q, k, v = _project_qkv(params, x, dims)
    q = apply_rope(q, positions[None, :], dims.rope_theta)
    k = apply_rope(k, positions[None, :], dims.rope_theta)
    out = chunked_attention(
        q, k, v, positions, positions, dims.groups, dims.sliding_window, q_chunk
    )
    out = matmul(out.reshape(B, S, dims.q_dim), params["w_o"])
    if return_kv:
        return out, (k, v)
    return out


# ---------------------------------------------------------------------------
# KV caches (full + ring-buffer window)
# ---------------------------------------------------------------------------


def make_kv_cache(batch: int, max_len: int, dims: AttnDims, dtype=jnp.bfloat16) -> Params:
    return {
        "k": jnp.zeros((batch, max_len, dims.num_kv_heads, dims.head_dim), dtype),
        "v": jnp.zeros((batch, max_len, dims.num_kv_heads, dims.head_dim), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def make_window_cache(batch: int, dims: AttnDims, dtype=jnp.bfloat16) -> Params:
    w = dims.sliding_window
    assert w is not None
    cache = make_kv_cache(batch, w, dims, dtype)
    cache["slot_pos"] = jnp.full((w,), -1, jnp.int32)
    return cache


def prefill_into_cache(cache: Params, k: jnp.ndarray, v: jnp.ndarray) -> Params:
    """Write a prefilled (k, v) prefix into a *full* cache starting at 0."""
    S = k.shape[1]
    cache = dict(cache)
    cache["k"] = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0))
    cache["v"] = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0))
    cache["pos"] = jnp.asarray(S, jnp.int32)
    return cache


def _cache_write(buf: jnp.ndarray, new: jnp.ndarray, slot: jnp.ndarray) -> jnp.ndarray:
    """Write one token into the cache at ``slot`` (traced).

    Two lowerings, selected by REPRO_DECODE_WRITE:
      * "where" (default): masked elementwise select over the seq dim —
        purely LOCAL under any sharding of that dim (the write fuses into
        the donated output buffer on TPU).  A dynamic-update-slice at a
        traced index into a sharded dim instead lowers as
        all-gather + update + reslice: the whole cache crosses the wire
        every step (measured: 2 TB/step for qwen2.5-32b decode_32k).
      * "dus": the naive dynamic_update_slice (kept for §Perf baselines).
    """
    import os as _os

    new = new.astype(buf.dtype)
    if _os.environ.get("REPRO_DECODE_WRITE", "where") == "dus":
        start = (0,) * buf.ndim
        start = (0, slot) + (0,) * (buf.ndim - 2)
        return jax.lax.dynamic_update_slice(buf, new, start)
    S = buf.shape[1]
    mask = jax.lax.broadcasted_iota(jnp.int32, (1, S) + (1,) * (buf.ndim - 2), 1) == slot
    return jnp.where(mask, jnp.broadcast_to(new, buf.shape), buf)


def _cache_write_ragged(buf: jnp.ndarray, new: jnp.ndarray, slots: jnp.ndarray) -> jnp.ndarray:
    """Write one token per row at PER-ROW slots (traced int32 [B]).

    Same masked-select lowering as ``_cache_write`` (local under sharding of
    the seq dim), with the slot index varying across the batch — the ragged
    case of the serving engine, where every cache row sits at its own
    position.
    """
    new = new.astype(buf.dtype)
    S = buf.shape[1]
    iota = jax.lax.broadcasted_iota(jnp.int32, (1, S) + (1,) * (buf.ndim - 2), 1)
    mask = iota == slots.reshape((-1,) + (1,) * (buf.ndim - 1))
    return jnp.where(mask, jnp.broadcast_to(new, buf.shape), buf)


def gqa_decode_ragged(
    params: Params,
    x: jnp.ndarray,  # [B, 1, d]
    cache: Params,
    dims: AttnDims,
):
    """One decode step with PER-ROW cache positions (``cache["pos"]``: [B]).

    This is the serving engine's slot-cache path: rows of one batch belong to
    different requests whose prefixes have different lengths (ragged
    continuous batching), so rope positions, the cache write, and the
    validity mask are all per-row.  Attention runs through
    ``kernels.ops.decode_attention`` — the Pallas flash-decode kernel on TPU,
    its jnp oracle elsewhere — which takes exactly this per-row ``lengths``
    contract.  Full (non-windowed) caches only.
    """
    from repro.kernels import ops as kernel_ops

    B = x.shape[0]
    pos = cache["pos"]  # int32 [B]
    q, k_new, v_new = _project_qkv(params, x, dims)
    pos_b = pos[:, None]  # [B, 1]
    q = apply_rope(q, pos_b, dims.rope_theta)
    k_new = apply_rope(k_new, pos_b, dims.rope_theta)

    if "slot_pos" in cache:
        raise NotImplementedError("ragged decode supports full caches only")
    new_cache = dict(cache)
    new_cache["k"] = _cache_write_ragged(cache["k"], k_new, pos)
    new_cache["v"] = _cache_write_ragged(cache["v"], v_new, pos)
    new_cache["pos"] = pos + 1

    out = kernel_ops.decode_attention(
        q[:, 0], new_cache["k"], new_cache["v"], pos + 1
    )
    out = matmul(out.reshape(B, 1, dims.q_dim), params["w_o"])
    return out, new_cache


def _paged_token_write(
    pool: jnp.ndarray,  # [NB, bs, ...] physical block pool
    new: jnp.ndarray,  # [B, 1, ...] one token per row
    table: jnp.ndarray,  # [B, n_logical] i32
    pos: jnp.ndarray,  # [B] i32 — position the token lands at
) -> jnp.ndarray:
    """Scatter one token per row into the pool through the block table."""
    bs = pool.shape[1]
    logical = pos // bs
    offset = pos % bs
    phys = jnp.take_along_axis(table, logical[:, None], axis=1)[:, 0]  # [B]
    return pool.at[phys, offset].set(new[:, 0].astype(pool.dtype))


def gqa_decode_paged(
    params: Params,
    x: jnp.ndarray,  # [B, 1, d]
    cache: Params,
    dims: AttnDims,
    seq_len: int,
):
    """One decode step against a PAGED slot store.

    ``cache`` holds the physical block pool plus per-row indirection:
    ``{"k"/"v": [NB, bs, kv, hd], "pos": i32 [B], "table": i32 [B, nlog]}``.
    Same per-row ragged math as ``gqa_decode_ragged`` — rope positions, the
    token write, and validity all keyed by ``pos`` — but reads and writes go
    through the block table.  Attention runs through
    ``kernels.ops.paged_decode_attention`` (scalar-prefetch Pallas kernel on
    TPU; gather-to-``seq_len`` + dense oracle elsewhere, which keeps paged
    decode bitwise identical to the dense slot path).  The engine guarantees
    the block containing ``pos`` is exclusively owned (copy-on-write happens
    at allocation time), so the write never touches a shared block.
    """
    from repro.kernels import ops as kernel_ops

    B = x.shape[0]
    pos = cache["pos"]  # int32 [B]
    table = cache["table"]  # int32 [B, n_logical]
    q, k_new, v_new = _project_qkv(params, x, dims)
    pos_b = pos[:, None]  # [B, 1]
    q = apply_rope(q, pos_b, dims.rope_theta)
    k_new = apply_rope(k_new, pos_b, dims.rope_theta)

    new_cache = dict(cache)
    new_cache["k"] = _paged_token_write(cache["k"], k_new, table, pos)
    new_cache["v"] = _paged_token_write(cache["v"], v_new, table, pos)
    new_cache["pos"] = pos + 1

    out = kernel_ops.paged_decode_attention(
        q[:, 0], new_cache["k"], new_cache["v"], table, pos + 1, seq_len=seq_len
    )
    out = matmul(out.reshape(B, 1, dims.q_dim), params["w_o"])
    return out, new_cache


def gqa_decode(
    params: Params,
    x: jnp.ndarray,  # [B, 1, d]
    cache: Params,
    dims: AttnDims,
):
    """One decode step against a full or windowed cache."""
    B = x.shape[0]
    pos = cache["pos"]
    q, k_new, v_new = _project_qkv(params, x, dims)
    pos_b = jnp.full((B, 1), pos, jnp.int32)
    q = apply_rope(q, pos_b, dims.rope_theta)
    k_new = apply_rope(k_new, pos_b, dims.rope_theta)

    windowed = "slot_pos" in cache
    S_cache = cache["k"].shape[1]
    slot = jnp.where(windowed, pos % S_cache, jnp.minimum(pos, S_cache - 1))

    new_cache = dict(cache)
    new_cache["k"] = _cache_write(cache["k"], k_new, slot)
    new_cache["v"] = _cache_write(cache["v"], v_new, slot)
    new_cache["pos"] = pos + 1

    if windowed:
        slot_pos = cache["slot_pos"].at[slot].set(pos)
        new_cache["slot_pos"] = slot_pos
        k_positions = slot_pos
        window = None  # ring buffer already bounds the window
    else:
        k_positions = jnp.where(
            jnp.arange(S_cache) <= pos, jnp.arange(S_cache), -1
        ).astype(jnp.int32)
        window = dims.sliding_window

    out = _attend_block(
        q,
        new_cache["k"],
        new_cache["v"],
        jnp.full((1,), pos, jnp.int32),
        k_positions,
        dims.groups,
        window,
    )
    out = matmul(out.reshape(B, 1, dims.q_dim), params["w_o"])
    return out, new_cache


# ---------------------------------------------------------------------------
# MLA — multi-head latent attention (DeepSeek-V2)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MlaDims:
    d_model: int
    num_heads: int
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128
    rope_theta: float = 1e4

    @property
    def qk_head_dim(self) -> int:
        return self.qk_nope_head_dim + self.qk_rope_head_dim


def mla_init(key, dims: MlaDims) -> Params:
    ks = jax.random.split(key, 6)
    H = dims.num_heads
    return {
        # queries: full-rank projection to per-head (nope + rope) dims
        "w_q": dense_init(ks[0], dims.d_model, H * dims.qk_head_dim),
        # joint KV low-rank compression
        "w_dkv": dense_init(ks[1], dims.d_model, dims.kv_lora_rank),
        "w_kpe": dense_init(ks[2], dims.d_model, dims.qk_rope_head_dim),
        # up-projections out of the latent
        "w_uk": dense_init(ks[3], dims.kv_lora_rank, H * dims.qk_nope_head_dim),
        "w_uv": dense_init(ks[4], dims.kv_lora_rank, H * dims.v_head_dim),
        "w_o": dense_init(ks[5], H * dims.v_head_dim, dims.d_model),
        "norm_ckv": layers.rmsnorm_init(dims.kv_lora_rank),
    }


def _mla_q(params: Params, x: jnp.ndarray, dims: MlaDims, positions: jnp.ndarray):
    B, S, _ = x.shape
    H = dims.num_heads
    q = matmul(x, params["w_q"]).reshape(B, S, H, dims.qk_head_dim)
    q_nope = q[..., : dims.qk_nope_head_dim]
    q_pe = apply_rope(q[..., dims.qk_nope_head_dim :], positions, dims.rope_theta)
    return q_nope, q_pe


def _mla_latent(params: Params, x: jnp.ndarray, dims: MlaDims, positions: jnp.ndarray):
    c_kv = layers.rmsnorm(params["norm_ckv"], matmul(x, params["w_dkv"]))
    k_pe = matmul(x, params["w_kpe"])[:, :, None, :]  # single shared rope head
    k_pe = apply_rope(k_pe, positions, dims.rope_theta)[:, :, 0, :]
    return c_kv, k_pe


def mla_forward(
    params: Params,
    x: jnp.ndarray,
    dims: MlaDims,
    positions: jnp.ndarray | None = None,
    q_chunk: int = 1024,
    return_latent: bool = False,
):
    """Train/prefill MLA: expand k/v out of the latent, attend causally."""
    B, S, _ = x.shape
    H = dims.num_heads
    if positions is None:
        positions = jnp.arange(S, dtype=jnp.int32)
    pos2 = positions[None, :]
    q_nope, q_pe = _mla_q(params, x, dims, pos2)
    c_kv, k_pe = _mla_latent(params, x, dims, pos2)

    k_nope = matmul(c_kv, params["w_uk"]).reshape(B, S, H, dims.qk_nope_head_dim)
    v = matmul(c_kv, params["w_uv"]).reshape(B, S, H, dims.v_head_dim)
    q = jnp.concatenate([q_nope, q_pe], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_pe[:, :, None, :], (B, S, H, dims.qk_rope_head_dim))],
        axis=-1,
    )
    out = chunked_attention(q, k, v, positions, positions, groups=1, q_chunk=q_chunk)
    out = matmul(out.reshape(B, S, H * dims.v_head_dim), params["w_o"])
    if return_latent:
        return out, (c_kv, k_pe)
    return out


def make_mla_cache(batch: int, max_len: int, dims: MlaDims, dtype=jnp.bfloat16) -> Params:
    return {
        "c_kv": jnp.zeros((batch, max_len, dims.kv_lora_rank), dtype),
        "k_pe": jnp.zeros((batch, max_len, dims.qk_rope_head_dim), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def mla_prefill_into_cache(cache: Params, c_kv: jnp.ndarray, k_pe: jnp.ndarray) -> Params:
    S = c_kv.shape[1]
    cache = dict(cache)
    cache["c_kv"] = jax.lax.dynamic_update_slice(
        cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), (0, 0, 0)
    )
    cache["k_pe"] = jax.lax.dynamic_update_slice(
        cache["k_pe"], k_pe.astype(cache["k_pe"].dtype), (0, 0, 0)
    )
    cache["pos"] = jnp.asarray(S, jnp.int32)
    return cache


def _mla_absorbed_attend(
    params: Params,
    q_nope: jnp.ndarray,  # [B, 1, H, nope_dim]
    q_pe: jnp.ndarray,  # [B, 1, H, rope_dim]
    c_kv: jnp.ndarray,  # [B, S, lora]
    k_pe: jnp.ndarray,  # [B, S, rope_dim]
    pos: jnp.ndarray,  # int32 [B] — per-row position of the new token
    dims: MlaDims,
) -> jnp.ndarray:
    """Absorbed-latent attention shared by the scalar- and ragged-position
    decodes: score and mix *in latent space* — the per-step cost is
    O(S * (lora + rope_dim)) per head instead of O(S * head_dim * 2) with
    re-expanded keys/values.  This is the inference win MLA exists for.
    """
    B, S_cache = c_kv.shape[0], c_kv.shape[1]
    H = dims.num_heads
    # absorb W_uk into the query:  q_lat[b,h,r] = sum_d q_nope[b,h,d] W_uk[r,(h,d)]
    w_uk = params["w_uk"].reshape(dims.kv_lora_rank, H, dims.qk_nope_head_dim)
    q_lat = jnp.einsum(
        "bhd,rhd->bhr", q_nope[:, 0].astype(jnp.bfloat16), w_uk.astype(jnp.bfloat16)
    )
    scores = jnp.einsum("bhr,bsr->bhs", q_lat, c_kv).astype(jnp.float32)
    scores = scores + jnp.einsum(
        "bhd,bsd->bhs", q_pe[:, 0].astype(jnp.float32), k_pe.astype(jnp.float32)
    )
    scores = scores / np.sqrt(dims.qk_head_dim)
    valid = jnp.arange(S_cache)[None, :] <= pos[:, None]  # [B, S]
    scores = jnp.where(valid[:, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(c_kv.dtype)
    out_lat = jnp.einsum("bhs,bsr->bhr", probs, c_kv)  # [B,H,lora]
    w_uv = params["w_uv"].reshape(dims.kv_lora_rank, H, dims.v_head_dim)
    out = jnp.einsum("bhr,rhd->bhd", out_lat, w_uv.astype(out_lat.dtype))
    return matmul(out.reshape(B, 1, H * dims.v_head_dim), params["w_o"])


def mla_decode_ragged(params: Params, x: jnp.ndarray, cache: Params, dims: MlaDims):
    """Absorbed MLA decode with PER-ROW cache positions (``cache["pos"]``: [B]).

    The serving engine's ragged slot-cache path: same latent-space math as
    ``mla_decode`` applied row-wise with per-row rope positions, cache
    writes, and validity masks.
    """
    pos = cache["pos"]  # int32 [B]
    pos_b = pos[:, None]
    q_nope, q_pe = _mla_q(params, x, dims, pos_b)  # [B,1,H,*]
    c_new, kpe_new = _mla_latent(params, x, dims, pos_b)

    new_cache = dict(cache)
    new_cache["c_kv"] = _cache_write_ragged(cache["c_kv"], c_new, pos)
    new_cache["k_pe"] = _cache_write_ragged(cache["k_pe"], kpe_new, pos)
    new_cache["pos"] = pos + 1

    out = _mla_absorbed_attend(
        params, q_nope, q_pe, new_cache["c_kv"], new_cache["k_pe"], pos, dims
    )
    return out, new_cache


def mla_decode_paged(
    params: Params, x: jnp.ndarray, cache: Params, dims: MlaDims, seq_len: int
):
    """Absorbed MLA decode against a PAGED latent pool.

    ``cache``: ``{"c_kv": [NB, bs, lora], "k_pe": [NB, bs, rope], "pos": [B],
    "table": [B, nlog]}``.  The latent rows are gathered to a contiguous
    ``seq_len`` view (the exact dense-slot shape, so the absorbed math is
    bitwise identical to ``mla_decode_ragged``); writes go through the table.
    """
    B = x.shape[0]
    pos = cache["pos"]  # int32 [B]
    table = cache["table"]
    pos_b = pos[:, None]
    q_nope, q_pe = _mla_q(params, x, dims, pos_b)  # [B,1,H,*]
    c_new, kpe_new = _mla_latent(params, x, dims, pos_b)

    new_cache = dict(cache)
    new_cache["c_kv"] = _paged_token_write(cache["c_kv"], c_new, table, pos)
    new_cache["k_pe"] = _paged_token_write(cache["k_pe"], kpe_new, table, pos)
    new_cache["pos"] = pos + 1

    c_virt = new_cache["c_kv"][table].reshape(B, -1, dims.kv_lora_rank)[:, :seq_len]
    kpe_virt = new_cache["k_pe"][table].reshape(B, -1, dims.qk_rope_head_dim)[
        :, :seq_len
    ]
    out = _mla_absorbed_attend(params, q_nope, q_pe, c_virt, kpe_virt, pos, dims)
    return out, new_cache


def mla_decode(params: Params, x: jnp.ndarray, cache: Params, dims: MlaDims):
    """Absorbed MLA decode against a shared-position cache (scalar ``pos``)."""
    B = x.shape[0]
    pos = cache["pos"]
    pos_b = jnp.full((B, 1), pos, jnp.int32)
    q_nope, q_pe = _mla_q(params, x, dims, pos_b)  # [B,1,H,*]
    c_new, kpe_new = _mla_latent(params, x, dims, pos_b)

    new_cache = dict(cache)
    new_cache["c_kv"] = _cache_write(cache["c_kv"], c_new, pos)
    new_cache["k_pe"] = _cache_write(cache["k_pe"], kpe_new, pos)
    new_cache["pos"] = pos + 1

    out = _mla_absorbed_attend(
        params,
        q_nope,
        q_pe,
        new_cache["c_kv"],
        new_cache["k_pe"],
        jnp.full((B,), pos, jnp.int32),
        dims,
    )
    return out, new_cache
