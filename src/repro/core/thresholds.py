"""Early-exit confidence thresholds: the reuse-based accuracy-ratio table
(paper §3.1 last paragraph) and the coupled threshold update (Eqs. 17-18).

The key trick reproduced here: record every validation sample's per-branch
(confidence, correctness) ONCE; any threshold setting C is then evaluated by
pure screening — no re-inference.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.types import DtoHyperParams, ModelProfile


@dataclasses.dataclass(frozen=True)
class ExitEvaluation:
    accuracy: float
    # stage_remaining[h] == I_h for stages 0..H (I_0 = 1; non-exit stages 1).
    stage_remaining: np.ndarray
    # Fraction of *all* tasks exiting at each branch (early branches + final).
    exit_fraction: np.ndarray


@dataclasses.dataclass
class ExitProfile:
    """Recorded one-shot validation outputs for a partitioned model.

    conf[n, b] / correct[n, b]: confidence and correctness of sample n at
    branch b.  Branches are the early exits in stage order, then the final
    head.  ``branch_stage`` maps branch -> 1-indexed stage.
    """

    conf: np.ndarray
    correct: np.ndarray
    branch_stage: tuple[int, ...]
    num_stages: int

    # -- cached extremes ----------------------------------------------------
    def __post_init__(self) -> None:
        self.conf = np.asarray(self.conf, np.float64)
        self.correct = np.asarray(self.correct, bool)
        ones = np.ones(self.num_early_branches)
        zeros = np.zeros(self.num_early_branches)
        self.acc_max = self.evaluate(ones).accuracy  # nobody exits early
        self.acc_min = self.evaluate(zeros).accuracy  # everyone exits earliest

    @property
    def num_early_branches(self) -> int:
        return len(self.branch_stage) - 1

    def evaluate(self, thresholds: Sequence[float]) -> ExitEvaluation:
        """Screen the recorded outputs under thresholds (one per early branch).

        A sample exits at the first early branch with conf >= c_b; the rest
        exit at the final head.  I_h is the *conditional* continue fraction
        at stage h (paper's remaining ratio).
        """
        c = np.asarray(thresholds, np.float64)
        if c.shape[0] != self.num_early_branches:
            raise ValueError(
                f"expected {self.num_early_branches} thresholds, got {c.shape[0]}"
            )
        n = self.conf.shape[0]
        exited = np.zeros(n, bool)
        acc_sum = 0.0
        stage_remaining = np.ones(self.num_stages + 1, np.float64)
        exit_frac = np.zeros(len(self.branch_stage), np.float64)
        for b in range(self.num_early_branches):
            reached = ~exited
            n_reached = int(reached.sum())
            takes = reached & (self.conf[:, b] >= c[b])
            n_takes = int(takes.sum())
            stage = self.branch_stage[b]
            stage_remaining[stage] = (
                1.0 - n_takes / n_reached if n_reached > 0 else 1.0
            )
            acc_sum += float(self.correct[takes, b].sum())
            exit_frac[b] = n_takes / n
            exited |= takes
        rest = ~exited
        acc_sum += float(self.correct[rest, -1].sum())
        exit_frac[-1] = rest.sum() / n
        return ExitEvaluation(
            accuracy=acc_sum / n,
            stage_remaining=stage_remaining,
            exit_fraction=exit_frac,
        )

    def accuracy_ratio_table(self, grid: np.ndarray) -> dict[tuple[float, ...], ExitEvaluation]:
        """Joint accuracy-ratio table over a threshold grid (paper: computed
        once from the recorded softmax outputs and then reused)."""
        from itertools import product

        table = {}
        for combo in product(grid.tolist(), repeat=self.num_early_branches):
            table[tuple(round(x, 6) for x in combo)] = self.evaluate(combo)
        return table

    def normalized_accuracy(self, acc: float) -> float:
        """(A - A_min) / (A_max - A_min) as used by U(T, A) (Eq. 9)."""
        span = max(self.acc_max - self.acc_min, 1e-9)
        return (acc - self.acc_min) / span


def synthetic_validation(
    seed: int,
    profile: ModelProfile,
    num_samples: int = 4000,
    num_classes: int = 1000,
    difficulty_correlation: float = 0.85,
    confidence_gain: float = 3.0,
    confidence_noise: float = 1.5,
) -> ExitProfile:
    """Generate a synthetic one-shot validation record matching Table 2.

    Model: each sample carries a latent difficulty; branch b classifies it
    correctly with marginal probability == the branch accuracy A_b (Gaussian
    copula across branches so early-correct samples tend to stay correct).
    Confidence is a noisy, increasing function of the sample's margin
    (A_b - u), so thresholding on confidence selects easier samples — the
    mechanism that makes early exit accuracy-positive on easy inputs.
    """
    rng = np.random.default_rng(seed)
    exit_stages = list(profile.exit_stages) + [profile.num_stages]
    accs = np.array([profile.branch_accuracy[h - 1] for h in exit_stages], np.float64)
    B = accs.shape[0]

    z_shared = rng.standard_normal((num_samples, 1))
    z_local = rng.standard_normal((num_samples, B))
    rho = difficulty_correlation
    z = rho * z_shared + np.sqrt(1.0 - rho**2) * z_local
    # u ~ U(0,1) marginally (Gaussian copula): u[n,b] is sample n's
    # "effective difficulty" as seen by branch b.
    from math import erf

    u = 0.5 * (1.0 + np.vectorize(erf)(z / np.sqrt(2.0)))
    correct = u < accs[None, :]

    margin = accs[None, :] - u
    raw = confidence_gain * margin + confidence_noise * rng.standard_normal(
        (num_samples, B)
    )
    floor = 1.0 / num_classes
    conf = floor + (1.0 - floor) / (1.0 + np.exp(-raw))
    conf = np.clip(conf, floor, 1.0 - 1e-9)

    return ExitProfile(
        conf=conf,
        correct=correct,
        branch_stage=tuple(exit_stages),
        num_stages=profile.num_stages,
    )


# ---------------------------------------------------------------------------
# Coupled threshold adjustment (paper Eqs. 17-18, Alg. 3 lines 5-8).
# ---------------------------------------------------------------------------


def delay_impact(
    phi_stage_nodes: np.ndarray,
    omega_stage_nodes: np.ndarray,
    total_phi: float,
    I_h: float,
    I_h_new: float,
) -> float:
    """sum_i Delta D_i^h (Eq. 17) over the stage's nodes: early exit is
    'offloading to a virtual node', so scaling I rescales the downstream
    gradient Omega."""
    if I_h <= 1e-9 or total_phi <= 1e-12:
        # no load (e.g. a measured topology before any arrival lands in the
        # telemetry window): a threshold move cannot change the delay
        return 0.0
    scale = (I_h_new - I_h) / I_h
    return float(np.sum(phi_stage_nodes / total_phi * scale * omega_stage_nodes))


@dataclasses.dataclass(frozen=True)
class ThresholdDecision:
    thresholds: np.ndarray
    stage_remaining: np.ndarray
    accuracy: float
    delta_u: float
    changed: bool


def threshold_step(
    exit_profile: ExitProfile,
    thresholds: np.ndarray,
    branch_index: int,
    phi_stage_nodes: np.ndarray,
    omega_stage_nodes: np.ndarray,
    total_phi: float,
    hyper: DtoHyperParams,
) -> ThresholdDecision:
    """Try c_h +/- tau_c for one branch; apply the move minimizing Delta U
    if Delta U < 0 (Alg. 3 lines 6-8).

    Note: Omega here must NOT include the receiver-side penalty explosion of
    an infeasible state beyond what Eq. 15 already carries — we pass whatever
    the DTO-O round computed, exactly as the distributed algorithm would.
    """
    base = exit_profile.evaluate(thresholds)
    stage = exit_profile.branch_stage[branch_index]
    best = ThresholdDecision(
        thresholds=thresholds.copy(),
        stage_remaining=base.stage_remaining,
        accuracy=base.accuracy,
        delta_u=0.0,
        changed=False,
    )
    for step in (+hyper.tau_c, -hyper.tau_c):
        cand = thresholds.copy()
        cand[branch_index] = float(np.clip(cand[branch_index] + step, 0.0, 1.0))
        if cand[branch_index] == thresholds[branch_index]:
            continue
        ev = exit_profile.evaluate(cand)
        dd = delay_impact(
            phi_stage_nodes,
            omega_stage_nodes,
            total_phi,
            I_h=float(base.stage_remaining[stage]),
            I_h_new=float(ev.stage_remaining[stage]),
        )
        d_acc_norm = exit_profile.normalized_accuracy(
            ev.accuracy
        ) - exit_profile.normalized_accuracy(base.accuracy)
        du = hyper.utility_a * dd - (1.0 - hyper.utility_a) * d_acc_norm
        if du < best.delta_u:
            best = ThresholdDecision(
                thresholds=cand,
                stage_remaining=ev.stage_remaining,
                accuracy=ev.accuracy,
                delta_u=du,
                changed=True,
            )
    return best
