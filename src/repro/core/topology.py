"""Edge-network topology builders (paper §4.1 deployment) + dynamic mutations."""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.types import ModelProfile, Topology

# Effective inference throughput (GFLOP/s) of the three Jetson device
# families, per working mode (paper §4.1: "the fastest mode (mode 0 of AGX)
# achieves inference speeds approximately 5x faster than the slowest mode
# (mode 1 of TX2)").  Mode 0 is the fast mode.
JETSON_CAPACITY_GFLOPS: dict[str, tuple[float, float]] = {
    "tx2": (60.0, 40.0),
    "nx": (100.0, 70.0),
    "agx": (200.0, 130.0),
}
CAPACITY_POOL = np.array(
    [c for modes in JETSON_CAPACITY_GFLOPS.values() for c in modes], np.float64
)


@dataclasses.dataclass(frozen=True)
class NetworkSpec:
    """Knobs for the random staged deployment of paper §4.1."""

    num_eds: int = 50
    es_per_stage: tuple[int, int] = (4, 6)  # inclusive range, skewed to fewer late
    receivers_per_node: tuple[int, int] = (2, 4)  # inclusive range
    ed_bw_mbps: tuple[float, float] = (1.0, 10.0)  # ED -> ES
    es_bw_mbps: tuple[float, float] = (10.0, 20.0)  # ES -> ES
    ed_arrival_rate: tuple[float, float] = (0.5, 1.5)  # tasks/s scale, x rate knob


def _stage_sizes(rng: np.random.Generator, spec: NetworkSpec, num_stages: int) -> list[int]:
    lo, hi = spec.es_per_stage
    sizes = []
    for h in range(num_stages):
        # Skew later stages towards fewer ESs (early-exit thins traffic).
        frac = h / max(num_stages - 1, 1)
        mean = hi - frac * (hi - lo)
        size = int(np.clip(round(rng.normal(mean, 0.7)), lo, hi))
        sizes.append(size)
    return sizes


def build_edge_network(
    seed: int,
    profile: ModelProfile,
    spec: NetworkSpec | None = None,
    arrival_rate_scale: float = 1.0,
    capacity_scale: float = 1.0,
) -> Topology:
    """Random staged deployment: EDs -> S^1 -> ... -> S^H.

    Every offloader is wired to 2-4 receivers in the next stage; wiring
    guarantees every receiver has at least one predecessor (otherwise it
    would be dead weight) and every offloader at least one successor.
    """
    spec = spec or NetworkSpec()
    rng = np.random.default_rng(seed)
    H = profile.num_stages

    sizes = [spec.num_eds] + _stage_sizes(rng, spec, H)
    stage_of: list[int] = []
    for h, size in enumerate(sizes):
        stage_of += [h] * size
    node_stage = np.asarray(stage_of, np.int32)
    num_nodes = node_stage.shape[0]

    node_ids_at = []
    start = 0
    for size in sizes:
        node_ids_at.append(np.arange(start, start + size, dtype=np.int32))
        start += size

    mu = np.full(num_nodes, np.inf, np.float64)
    for h in range(1, H + 1):
        ids = node_ids_at[h]
        mu[ids] = rng.choice(CAPACITY_POOL, size=ids.shape[0]) * capacity_scale

    phi_ext = np.zeros(num_nodes, np.float64)
    lo, hi = spec.ed_arrival_rate
    phi_ext[node_ids_at[0]] = rng.uniform(lo, hi, size=sizes[0]) * arrival_rate_scale

    # --- wiring ----------------------------------------------------------
    edge_src: list[int] = []
    edge_dst: list[int] = []
    edge_rate: list[float] = []
    for h in range(0, H):  # offloader stage h -> receiver stage h+1
        senders = node_ids_at[h]
        receivers = node_ids_at[h + 1]
        rlo, rhi = spec.receivers_per_node
        bw_lo, bw_hi = spec.ed_bw_mbps if h == 0 else spec.es_bw_mbps
        chosen: list[np.ndarray] = []
        for s in senders:
            k = min(int(rng.integers(rlo, rhi + 1)), receivers.shape[0])
            picks = rng.choice(receivers, size=k, replace=False)
            chosen.append(np.sort(picks))
        # Ensure each receiver has >=1 predecessor.
        covered = np.unique(np.concatenate(chosen)) if chosen else np.array([], np.int32)
        for r in receivers:
            if r not in covered:
                s_idx = int(rng.integers(0, senders.shape[0]))
                chosen[s_idx] = np.unique(np.append(chosen[s_idx], r))
        for s, picks in zip(senders, chosen):
            for d in picks:
                edge_src.append(int(s))
                edge_dst.append(int(d))
                edge_rate.append(float(rng.uniform(bw_lo, bw_hi)))

    order = np.lexsort((np.asarray(edge_dst), np.asarray(edge_src)))
    edge_src_a = np.asarray(edge_src, np.int32)[order]
    edge_dst_a = np.asarray(edge_dst, np.int32)[order]
    edge_rate_a = np.asarray(edge_rate, np.float64)[order]

    counts = np.bincount(edge_src_a, minlength=num_nodes)
    edge_offsets = np.concatenate([[0], np.cumsum(counts)]).astype(np.int32)

    topo = Topology(
        node_stage=node_stage,
        mu=mu,
        phi_ext=phi_ext,
        edge_src=edge_src_a,
        edge_dst=edge_dst_a,
        edge_rate=edge_rate_a,
        edge_offsets=edge_offsets,
    )
    topo.validate()
    return topo


def build_uniform_network(
    seed: int,
    profile: ModelProfile,
    num_eds: int = 20,
    es_per_stage: int = 4,
    capacity_gflops: float = 120.0,
    bw_mbps: float = 15.0,
    ed_arrival_rate: float = 1.0,
    fully_connected: bool = True,
) -> Topology:
    """Homogeneous deployment used by the Fig. 9 ablation (same #ES per stage,
    same capacity, same links)."""
    rng = np.random.default_rng(seed)
    H = profile.num_stages
    sizes = [num_eds] + [es_per_stage] * H
    node_stage = np.concatenate([np.full(s, h, np.int32) for h, s in enumerate(sizes)])
    num_nodes = node_stage.shape[0]
    mu = np.full(num_nodes, np.inf, np.float64)
    mu[node_stage > 0] = capacity_gflops
    phi_ext = np.zeros(num_nodes, np.float64)
    phi_ext[node_stage == 0] = ed_arrival_rate

    node_ids_at = [np.nonzero(node_stage == h)[0] for h in range(H + 1)]
    edge_src, edge_dst, edge_rate = [], [], []
    for h in range(0, H):
        for s in node_ids_at[h]:
            receivers = node_ids_at[h + 1]
            if not fully_connected:
                k = min(3, receivers.shape[0])
                receivers = rng.choice(receivers, size=k, replace=False)
            for d in np.sort(receivers):
                edge_src.append(int(s))
                edge_dst.append(int(d))
                edge_rate.append(bw_mbps)
    edge_src_a = np.asarray(edge_src, np.int32)
    edge_dst_a = np.asarray(edge_dst, np.int32)
    edge_rate_a = np.asarray(edge_rate, np.float64)
    counts = np.bincount(edge_src_a, minlength=num_nodes)
    edge_offsets = np.concatenate([[0], np.cumsum(counts)]).astype(np.int32)
    topo = Topology(node_stage, mu, phi_ext, edge_src_a, edge_dst_a, edge_rate_a, edge_offsets)
    topo.validate()
    return topo


# ---------------------------------------------------------------------------
# Dynamic-environment mutations (paper §4.3) — all return fresh Topology.
# ---------------------------------------------------------------------------


def with_arrival_rates(topo: Topology, rng: np.random.Generator, lo: float, hi: float) -> Topology:
    phi = topo.phi_ext.copy()
    eds = topo.nodes_at_stage(0)
    phi[eds] = rng.uniform(lo, hi, size=eds.shape[0])
    return dataclasses.replace(topo, phi_ext=phi)


def with_resampled_capacities(
    topo: Topology, rng: np.random.Generator, scale: float = 1.0
) -> Topology:
    """Re-draw each ES's computing mode (paper: 'adjust the computation mode')."""
    mu = topo.mu.copy()
    ess = np.nonzero(topo.node_stage > 0)[0]
    mu[ess] = rng.choice(CAPACITY_POOL, size=ess.shape[0]) * scale
    return dataclasses.replace(topo, mu=mu)


def with_capacity_scale(topo: Topology, scale: float) -> Topology:
    mu = topo.mu.copy()
    ess = topo.node_stage > 0
    mu[ess] = mu[ess] * scale
    return dataclasses.replace(topo, mu=mu)


def with_link_degradation(
    topo: Topology,
    pairs: Sequence[tuple[int, int]],
    factor: float,
) -> Topology:
    """Scale the bandwidth of the named (src, dst) links by ``factor``
    (congestion / interference on specific hops, paper §4.3's dynamic links).

    Unknown pairs are ignored — the caller may hold a pair list predating a
    node failure that dropped some of those edges.
    """
    if factor <= 0:
        raise ValueError("link degradation factor must be positive")
    rate = topo.edge_rate.copy()
    index = {
        (int(s), int(d)): i
        for i, (s, d) in enumerate(zip(topo.edge_src, topo.edge_dst))
    }
    for pair in pairs:
        i = index.get((int(pair[0]), int(pair[1])))
        if i is not None:
            rate[i] = rate[i] * factor
    return dataclasses.replace(topo, edge_rate=rate)


def with_node_failure(topo: Topology, dead_node: int) -> Topology:
    """Drop a failed ES: remove its in/out edges (capacity -> 0 keeps indexing
    stable; the router must renormalize offloading probabilities).

    Raises if removing the node would strand an offloader with no successor —
    the caller must then trigger an elastic re-mesh instead.
    """
    if topo.node_stage[dead_node] == 0:
        raise ValueError("EDs do not fail in this model; they stop producing instead")
    keep = (topo.edge_src != dead_node) & (topo.edge_dst != dead_node)
    edge_src = topo.edge_src[keep]
    edge_dst = topo.edge_dst[keep]
    edge_rate = topo.edge_rate[keep]
    counts = np.bincount(edge_src, minlength=topo.num_nodes)
    H = int(topo.node_stage.max())
    deg_needed = (topo.node_stage < H) & (np.arange(topo.num_nodes) != dead_node)
    # EDs/ESs that still must offload:
    alive_senders = np.nonzero(deg_needed)[0]
    if np.any(counts[alive_senders] == 0):
        raise RuntimeError("node failure strands an offloader; elastic re-mesh required")
    mu = topo.mu.copy()
    mu[dead_node] = 1e-9  # effectively dead; no edges reference it anymore
    edge_offsets = np.concatenate([[0], np.cumsum(counts)]).astype(np.int32)
    return dataclasses.replace(
        topo,
        mu=mu,
        edge_src=edge_src,
        edge_dst=edge_dst,
        edge_rate=edge_rate,
        edge_offsets=edge_offsets,
    )
