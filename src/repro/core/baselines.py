"""Baseline offloading strategies from paper §4.1: CF, BF, NGTO, GA.

All baselines use the SAME threshold-adaptation machinery as DTO-EE (the
paper adapts thresholds across all baselines with equal frequency/step), so
a baseline here only decides the offloading probabilities P.
"""
from __future__ import annotations

import dataclasses

import numpy as np

import jax.numpy as jnp

from repro.core import queueing
from repro.core.types import DtoHyperParams, ModelProfile, Topology


def computing_first(topo: Topology) -> jnp.ndarray:
    """CF: offload proportionally to receiver computing capacity mu_j."""
    w = topo.mu[topo.edge_dst].copy()
    w[~np.isfinite(w)] = 0.0
    return _normalize_per_source(topo, w)


def bandwidth_first(topo: Topology) -> jnp.ndarray:
    """BF: offload proportionally to link bandwidth r_{i,j}."""
    return _normalize_per_source(topo, topo.edge_rate.copy())


def _normalize_per_source(topo: Topology, w: np.ndarray) -> jnp.ndarray:
    w = np.maximum(w, 1e-12)
    sums = np.zeros(topo.num_nodes)
    np.add.at(sums, topo.edge_src, w)
    return jnp.asarray(w / sums[topo.edge_src], jnp.float32)


# ---------------------------------------------------------------------------
# NGTO: non-cooperative game task offloading [29].
# Each offloader performs a selfish *myopic* best response — minimizing only
# its own immediate hop cost (transmission + receiver M/D/1-PS delay) given
# the other offloaders' current strategies — updated in round-robin order
# until a Nash equilibrium (no offloader moves).  The paper's critique (and
# what we reproduce): myopia w.r.t. downstream stages + long cyclic decision
# time.
# ---------------------------------------------------------------------------


def _simplex_project(v: np.ndarray) -> np.ndarray:
    """Euclidean projection onto the probability simplex."""
    u = np.sort(v)[::-1]
    css = np.cumsum(u) - 1.0
    ind = np.arange(1, v.shape[0] + 1)
    cond = u - css / ind > 0
    rho = ind[cond][-1]
    theta = css[cond][-1] / rho
    return np.maximum(v - theta, 0.0)


def ngto(
    topo: Topology,
    profile: ModelProfile,
    stage_remaining: np.ndarray,
    max_sweeps: int = 30,
    br_iters: int = 40,
    br_lr: float = 0.05,
    tol: float = 1e-4,
) -> tuple[jnp.ndarray, int]:
    """Returns (p, round_robin_sweeps_used).  Pure numpy: the game runs on
    hosts, sequentially, by construction (that's its weakness)."""
    alpha = np.concatenate([[0.0], np.asarray(profile.alpha)])
    alpha_n = alpha[topo.node_stage]
    beta = np.concatenate([[0.0], np.asarray(profile.beta)])
    beta_e = beta[topo.node_stage[topo.edge_dst]]
    t_cm = beta_e / topo.edge_rate
    mu = np.where(np.isinf(topo.mu), 1e30, topo.mu)
    I_node = stage_remaining[topo.node_stage]

    deg = topo.out_degree()
    p = 1.0 / np.maximum(deg, 1)[topo.edge_src]

    H = topo.num_stages
    offloaders = np.nonzero(topo.node_stage < H)[0]

    def flows(p_vec: np.ndarray) -> np.ndarray:
        phi = topo.phi_ext.copy()
        for h in range(H):
            sel = topo.node_stage[topo.edge_src] == h
            inflow = np.zeros(topo.num_nodes)
            np.add.at(
                inflow,
                topo.edge_dst[sel],
                p_vec[sel] * phi[topo.edge_src[sel]] * I_node[topo.edge_src[sel]],
            )
            at = topo.node_stage == h + 1
            phi[at] = inflow[at]
        return phi

    sweeps = 0
    for sweep in range(max_sweeps):
        sweeps = sweep + 1
        moved = 0.0
        for i in offloaders:
            lo, hi = topo.edge_offsets[i], topo.edge_offsets[i + 1]
            if hi - lo <= 1:
                continue
            phi = flows(p)
            out_rate = phi[i] * I_node[i]  # tasks/s this offloader emits
            dsts = topo.edge_dst[lo:hi]
            # receiver background load excluding this offloader's share
            lam_all = phi * alpha_n
            own = p[lo:hi] * out_rate * alpha_n[dsts]
            lam_bg = lam_all[dsts] - own
            pi = p[lo:hi].copy()
            # projected gradient best response on the myopic hop cost
            for _ in range(br_iters):
                lam_j = lam_bg + pi * out_rate * alpha_n[dsts]
                gap = np.maximum(mu[dsts] - lam_j, 1e-6)
                # d/dp [ p*(t_cm + a/(mu-lam(p))) ]
                grad = (
                    t_cm[lo:hi]
                    + alpha_n[dsts] / gap
                    + pi * out_rate * alpha_n[dsts] ** 2 / gap**2
                )
                pi = _simplex_project(pi - br_lr * grad / (np.abs(grad).max() + 1e-12))
            moved = max(moved, float(np.abs(pi - p[lo:hi]).max()))
            p[lo:hi] = pi
        if moved < tol:
            break
    return jnp.asarray(p, jnp.float32), sweeps


# ---------------------------------------------------------------------------
# GA: genetic path search per ED [42].  Each ED gathers (possibly outdated)
# global state and searches a full source-routed path (one ES per stage)
# minimizing ITS OWN delay, then sends all its tasks down that path.
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class GaResult:
    # paths[ed] = tuple of node ids, one per stage 1..H
    paths: dict[int, tuple[int, ...]]
    p: jnp.ndarray  # effective per-edge split implied by the chosen paths
    generations: int


def _edge_lookup(topo: Topology) -> dict[tuple[int, int], int]:
    return {
        (int(s), int(d)): k
        for k, (s, d) in enumerate(zip(topo.edge_src, topo.edge_dst))
    }


def genetic_paths(
    topo: Topology,
    profile: ModelProfile,
    stage_remaining: np.ndarray,
    lam_snapshot: np.ndarray | None = None,
    seed: int = 0,
    pop_size: int = 24,
    generations: int = 15,
    mutate_prob: float = 0.25,
) -> GaResult:
    """Per-ED GA over source-routed paths, scored against a *snapshot* of
    node loads (the outdated-information failure mode the paper describes:
    every ED optimizes selfishly against the same stale lambda)."""
    rng = np.random.default_rng(seed)
    H = topo.num_stages
    alpha = np.concatenate([[0.0], np.asarray(profile.alpha)])
    beta = np.concatenate([[0.0], np.asarray(profile.beta)])
    mu = np.where(np.isinf(topo.mu), 1e30, topo.mu)
    lookup = _edge_lookup(topo)
    succ = {int(v): topo.successors(v).tolist() for v in range(topo.num_nodes)}
    if lam_snapshot is None:
        lam_snapshot = np.zeros(topo.num_nodes)

    def random_path(ed: int) -> tuple[int, ...]:
        path, cur = [], ed
        for _ in range(H):
            nxt = int(rng.choice(succ[cur]))
            path.append(nxt)
            cur = nxt
        return tuple(path)

    def path_delay(ed: int, path: tuple[int, ...]) -> float:
        cur, total, alive = ed, 0.0, 1.0
        for h, nxt in enumerate(path, start=1):
            e = lookup[(cur, nxt)]
            gap = max(mu[nxt] - lam_snapshot[nxt], 1e-6)
            hop = beta[h] / topo.edge_rate[e] + alpha[h] / gap
            total += alive * hop
            alive *= stage_remaining[h]
            cur = nxt
        return total

    def crossover(a: tuple[int, ...], b: tuple[int, ...], ed: int) -> tuple[int, ...]:
        """Hop-by-hop repair: prefer a's prefix / b's suffix where the edge
        exists, fall back to a random successor (keeps every child valid
        even when the parents were produced by mutation splices)."""
        cut = int(rng.integers(1, H)) if H > 1 else 0
        child: list[int] = []
        cur = ed
        for h in range(H):
            options = succ[cur]
            want = a[h] if h < cut else b[h]
            child.append(want if want in options else int(rng.choice(options)))
            cur = child[-1]
        return tuple(child)

    eds = topo.nodes_at_stage(0)
    paths: dict[int, tuple[int, ...]] = {}
    for ed in eds:
        pop = [random_path(int(ed)) for _ in range(pop_size)]
        for _ in range(generations):
            scored = sorted(pop, key=lambda pth: path_delay(int(ed), pth))
            elite = scored[: max(pop_size // 4, 2)]
            children = []
            while len(children) < pop_size - len(elite):
                a, b = rng.choice(len(elite), 2)
                child = crossover(elite[a], elite[b], int(ed))
                if rng.random() < mutate_prob:
                    # mutate one hop and repair the suffix
                    cut = int(rng.integers(0, H))
                    child = crossover(child[:cut] + random_path(int(ed))[cut:], child, int(ed))
                children.append(child)
            pop = elite + children
        paths[int(ed)] = min(pop, key=lambda pth: path_delay(int(ed), pth))

    p = paths_to_strategy(topo, profile, stage_remaining, paths)
    return GaResult(paths=paths, p=p, generations=generations)


def paths_to_strategy(
    topo: Topology,
    profile: ModelProfile,
    stage_remaining: np.ndarray,
    paths: dict[int, tuple[int, ...]],
) -> jnp.ndarray:
    """Convert per-ED source routes into effective per-edge splits: route the
    (exit-thinned) flow down each path, then normalize flow per offloader.
    Edges carrying no flow get probability 0 unless the node carries no flow
    at all (then uniform — it must still advertise a valid strategy)."""
    lookup = _edge_lookup(topo)
    flow = np.zeros(topo.num_edges)
    for ed, path in paths.items():
        rate, cur = float(topo.phi_ext[ed]), ed
        for h, nxt in enumerate(path, start=1):
            flow[lookup[(cur, nxt)]] += rate
            rate *= stage_remaining[h]
            cur = nxt
    sums = np.zeros(topo.num_nodes)
    np.add.at(sums, topo.edge_src, flow)
    deg = np.maximum(topo.out_degree(), 1)
    uniform = 1.0 / deg[topo.edge_src]
    has_flow = sums[topo.edge_src] > 0
    p = np.where(has_flow, flow / np.maximum(sums[topo.edge_src], 1e-12), uniform)
    return jnp.asarray(p, jnp.float32)


# ---------------------------------------------------------------------------
# Threshold adaptation for baselines (paper §4.1: "We adaptively adjust
# confidence thresholds across all baselines ... same update frequency and
# step size as DTO-EE").  A baseline only decides P; this runs the Eq. 17-18
# coupled adjustment against that fixed P, cycling branches like Alg. 3.
# ---------------------------------------------------------------------------


def adapt_thresholds_for_strategy(
    topo: Topology,
    profile: ModelProfile,
    exit_profile,
    p: jnp.ndarray,
    hyper: DtoHyperParams,
    thresholds0: np.ndarray | None = None,
    sweeps: int = 10,
) -> tuple[np.ndarray, np.ndarray, float]:
    """Returns (thresholds, stage_remaining, accuracy) adapted to P."""
    from repro.core import gradients
    from repro.core.thresholds import threshold_step

    thresholds = (
        np.asarray(thresholds0, np.float64)
        if thresholds0 is not None
        else np.full(exit_profile.num_early_branches, 0.8)
    )
    total_phi = float(topo.phi_ext.sum())
    ev = exit_profile.evaluate(thresholds)
    for _ in range(sweeps):
        changed_any = False
        for b in range(exit_profile.num_early_branches):
            I_node = jnp.asarray(ev.stage_remaining, jnp.float32)[
                jnp.asarray(topo.node_stage)
            ]
            phi, lam = queueing.steady_state_flows(p, topo, profile, I_node)
            _, omega = gradients.backward_recursion(
                p, topo, profile, I_node, lam, hyper
            )
            stage = exit_profile.branch_stage[b]
            nodes = topo.nodes_at_stage(stage)
            decision = threshold_step(
                exit_profile,
                thresholds,
                b,
                np.asarray(phi)[nodes],
                np.asarray(omega)[nodes],
                total_phi,
                hyper,
            )
            if decision.changed:
                thresholds = decision.thresholds
                ev = exit_profile.evaluate(thresholds)
                changed_any = True
        if not changed_any:
            break
    return thresholds, ev.stage_remaining, ev.accuracy
