"""Core datatypes for the DTO-EE control plane.

Units convention (keeps penalty / delay terms numerically sane):
  - compute        : GFLOPs (alpha) and GFLOP/s (mu, lam)
  - data sizes     : MB (beta)
  - bandwidth      : MB/s (edge rates)
  - time           : seconds
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class ModelProfile:
    """Per-stage cost/accuracy profile of a partitioned model (paper Table 2).

    Stages are 1-indexed in the paper (``M_1 .. M_H``); arrays here are
    0-indexed with entry ``h-1`` describing sub-model ``M_h``.
    """

    name: str
    # GFLOPs required to run sub-model M_h on one task (paper: alpha_h).
    alpha: tuple[float, ...]
    # Input size of sub-model M_h in MB (paper: beta_h). beta[0] is the size
    # of the raw task payload shipped from the ED.
    beta: tuple[float, ...]
    # exit_stage[h-1] == True iff sub-model M_h carries an exit branch b_h.
    has_exit: tuple[bool, ...]
    # Accuracy of the prediction made at stage h (exit branches and the
    # final head). Non-exit, non-final stages carry 0.0 placeholders.
    branch_accuracy: tuple[float, ...]

    def __post_init__(self) -> None:
        H = len(self.alpha)
        if not (len(self.beta) == len(self.has_exit) == len(self.branch_accuracy) == H):
            raise ValueError("profile arrays must share length H")
        if self.has_exit[-1]:
            raise ValueError("final stage is the mandatory exit; has_exit marks early branches only")

    @property
    def num_stages(self) -> int:
        return len(self.alpha)

    @property
    def exit_stages(self) -> tuple[int, ...]:
        """1-indexed stages carrying early-exit branches."""
        return tuple(h + 1 for h, e in enumerate(self.has_exit) if e)

    @property
    def total_gflops(self) -> float:
        return float(sum(self.alpha))


# ---------------------------------------------------------------------------
# Paper Table 2 profiles.
# ---------------------------------------------------------------------------

# ResNet101 split into 4 sub-models; exit branches on M_2 and M_3.
# beta_1 (the compressed input image) is not listed in Table 2; we use
# 0.15 MB (JPEG-compressed ImageNet sample), see DESIGN.md §9.
RESNET101_PROFILE = ModelProfile(
    name="resnet101",
    alpha=(2.21, 1.97, 1.97, 1.68),
    beta=(0.15, 0.77, 0.77, 0.77),
    has_exit=(False, True, True, False),
    branch_accuracy=(0.0, 0.470, 0.582, 0.681),
)

# BERT-large split into 5 sub-models; exit branches on M_2, M_3, M_4.
BERT_PROFILE = ModelProfile(
    name="bert",
    alpha=(6.44, 8.05, 8.08, 8.08, 8.08),
    beta=(0.01, 0.56, 0.56, 0.56, 0.56),
    has_exit=(False, True, True, True, False),
    branch_accuracy=(0.0, 0.552, 0.568, 0.572, 0.582),
)


@dataclasses.dataclass
class Topology:
    """A staged edge network in CSR-ish array form.

    Nodes ``0..num_nodes-1``.  ``node_stage[v] == 0`` marks an ED; stages
    ``1..H`` mark ESs holding sub-model ``M_h``.  Directed edges run only
    from stage ``h`` to stage ``h+1`` (the paper's pipeline arrangement).

    Edges are sorted by (src, dst); ``edge_offsets`` is the CSR row pointer
    over sources, so the successor set L_i of node i is
    ``edges[edge_offsets[i]:edge_offsets[i+1]]``.
    """

    node_stage: np.ndarray  # int32 [N]
    mu: np.ndarray  # float64 [N]  GFLOP/s (EDs: np.inf — they do not compute)
    phi_ext: np.ndarray  # float64 [N] external Poisson arrival rate; 0 for ESs
    edge_src: np.ndarray  # int32 [E]
    edge_dst: np.ndarray  # int32 [E]
    edge_rate: np.ndarray  # float64 [E]  MB/s
    edge_offsets: np.ndarray  # int32 [N+1] CSR over sources

    def __post_init__(self) -> None:
        self.node_stage = np.asarray(self.node_stage, np.int32)
        self.mu = np.asarray(self.mu, np.float64)
        self.phi_ext = np.asarray(self.phi_ext, np.float64)
        self.edge_src = np.asarray(self.edge_src, np.int32)
        self.edge_dst = np.asarray(self.edge_dst, np.int32)
        self.edge_rate = np.asarray(self.edge_rate, np.float64)
        self.edge_offsets = np.asarray(self.edge_offsets, np.int32)

    # -- sizes ------------------------------------------------------------
    @property
    def num_nodes(self) -> int:
        return int(self.node_stage.shape[0])

    @property
    def num_edges(self) -> int:
        return int(self.edge_src.shape[0])

    @property
    def num_stages(self) -> int:
        return int(self.node_stage.max())

    # -- views ------------------------------------------------------------
    def successors(self, v: int) -> np.ndarray:
        lo, hi = self.edge_offsets[v], self.edge_offsets[v + 1]
        return self.edge_dst[lo:hi]

    def out_edges(self, v: int) -> np.ndarray:
        lo, hi = self.edge_offsets[v], self.edge_offsets[v + 1]
        return np.arange(lo, hi, dtype=np.int32)

    def nodes_at_stage(self, h: int) -> np.ndarray:
        return np.nonzero(self.node_stage == h)[0].astype(np.int32)

    def out_degree(self) -> np.ndarray:
        return np.diff(self.edge_offsets)

    def validate(self) -> None:
        """Structural invariants: staged edges, sorted CSR, offloaders covered."""
        if self.edge_offsets.shape[0] != self.num_nodes + 1:
            raise ValueError("edge_offsets must have N+1 entries")
        if not np.all(np.diff(self.edge_offsets) >= 0):
            raise ValueError("edge_offsets must be monotone")
        src_stage = self.node_stage[self.edge_src]
        dst_stage = self.node_stage[self.edge_dst]
        if not np.all(dst_stage == src_stage + 1):
            raise ValueError("edges must connect stage h to stage h+1")
        # every LIVE node below the final stage must have >= 1 successor
        # (dead ESs keep their slot with mu ~ 0 and no edges; idle EDs with
        # no arrivals need none either)
        H = self.num_stages
        deg = self.out_degree()
        live_es = (self.node_stage > 0) & (self.mu > 1e-6)
        live_ed = (self.node_stage == 0) & (self.phi_ext > 0)
        need = (self.node_stage < H) & (live_es | live_ed)
        if not np.all(deg[need] >= 1):
            raise ValueError("every live non-final node needs at least one successor")
        if np.any(self.mu[self.node_stage > 0] <= 0):
            raise ValueError("ES capacity must be positive")
        if np.any(self.edge_rate <= 0):
            raise ValueError("edge rates must be positive")


@dataclasses.dataclass(frozen=True)
class DtoHyperParams:
    """Hyper-parameters of Algorithms 1-3."""

    tau_p: float = 0.15  # offloading step size (Eq. 19)
    tau_c: float = 0.05  # confidence-threshold step size
    penalty_k: float = 10.0  # exterior-point penalty factor K (Eq. 11)
    penalty_eps: float = 1e-3  # epsilon in Eq. 11
    rounds: int = 50  # communication rounds n per configuration phase
    threshold_every: int = 5  # update frequency m (Alg. 3 line 5)
    utility_a: float = 0.85  # weight a in U(T, A) (Eq. 9); delay in s vs acc in [0,1]

    def __post_init__(self) -> None:
        if not (0.0 < self.tau_p <= 1.0):
            raise ValueError("tau_p must lie in (0, 1]")


def stage_index_arrays(topo: Topology) -> list[np.ndarray]:
    """Edge indices grouped by source stage: groups[h] = edges with src at stage h."""
    src_stage = topo.node_stage[topo.edge_src]
    return [np.nonzero(src_stage == h)[0].astype(np.int32) for h in range(topo.num_stages)]
