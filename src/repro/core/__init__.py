"""DTO-EE control plane: the paper's primary contribution.

Topology + M/D/1-PS queueing + exterior-point penalty + the Omega/Delta
backward recursion + DTO-R / DTO-O / DTO-EE (Algorithms 1-3) + baselines
(CF, BF, NGTO, GA) + the discrete-event simulator that measures them.
"""
from repro.core.types import (
    BERT_PROFILE,
    DtoHyperParams,
    ModelProfile,
    RESNET101_PROFILE,
    Topology,
)

__all__ = [
    "BERT_PROFILE", "DtoHyperParams", "ModelProfile", "RESNET101_PROFILE", "Topology",
]
