"""Repulsive factors Delta (Eq. 15) and gradient info Omega (Eqs. 14, 16).

Two flavors:
  * ``delta_edges`` — per-edge Delta given the receivers' (lam, Omega), i.e.
    exactly what a DTO-O offloader computes from received RUS messages.
  * ``backward_recursion`` — the centralized oracle that runs the recursion
    to a fixed point over stages; used by tests (Lemma 1 / Eq. 22 checks)
    and by one-shot planners.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import queueing
from repro.core.types import DtoHyperParams, ModelProfile, Topology

_BIG = 1e8  # repulsive factor of an unstable receiver (on top of the penalty)


def delta_edges(
    p: jnp.ndarray,
    topo: Topology,
    profile: ModelProfile,
    lam: jnp.ndarray,
    omega: jnp.ndarray,
    hyper: DtoHyperParams,
) -> jnp.ndarray:
    """Delta_{i,j} per edge (Eq. 15) from receiver-side state (lam, omega).

    Delta_ij = mu_j a/(mu_j-lam_j)^2 + beta/r_ij + Omega_j
               + 2*K*Phi * max(0, a*(lam_j - mu_j + eps))
    """
    dst = topo.edge_dst
    alpha_n = jnp.asarray(queueing.alpha_per_node(topo, profile), jnp.float32)
    beta_e = jnp.asarray(queueing.beta_per_edge(topo, profile), jnp.float32)
    mu = jnp.asarray(np.where(np.isinf(topo.mu), 1e30, topo.mu), jnp.float32)
    total_phi = float(topo.phi_ext.sum())

    mu_d = mu[dst]
    lam_d = lam[dst]
    a_d = alpha_n[dst]
    gap = mu_d - lam_d
    stable = gap > 0
    congestion = jnp.where(stable, mu_d * a_d / jnp.where(stable, gap, 1.0) ** 2, _BIG)
    transmission = beta_e / jnp.asarray(topo.edge_rate, jnp.float32)
    pen = 2.0 * hyper.penalty_k * total_phi * jnp.maximum(
        0.0, a_d * (lam_d - mu_d + hyper.penalty_eps)
    )
    return congestion + transmission + omega[dst] + pen


def omega_from_delta(
    p: jnp.ndarray,
    topo: Topology,
    I_node: jnp.ndarray,
    delta: jnp.ndarray,
) -> jnp.ndarray:
    """Omega_i = I_i * sum_{j in L_i} p_ij * Delta_ij (Eq. 16); 0 at stage H."""
    contrib = p * delta
    omega = jax.ops.segment_sum(contrib, topo.edge_src, num_segments=topo.num_nodes)
    omega = omega * I_node
    is_last = jnp.asarray(topo.node_stage == topo.num_stages)
    return jnp.where(is_last, 0.0, omega)


def backward_recursion(
    p: jnp.ndarray,
    topo: Topology,
    profile: ModelProfile,
    I_node: jnp.ndarray,
    lam: jnp.ndarray,
    hyper: DtoHyperParams,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Exact (Delta, Omega) by sweeping stages H-1 .. 0 (centralized oracle)."""
    H = topo.num_stages
    src_stage = topo.node_stage[topo.edge_src]  # static numpy
    omega = jnp.zeros(topo.num_nodes, jnp.float32)
    delta = jnp.zeros(topo.num_edges, jnp.float32)
    for h in range(H - 1, -1, -1):
        d_all = delta_edges(p, topo, profile, lam, omega, hyper)
        sel = jnp.asarray((src_stage == h).astype(np.float32))
        delta = delta + d_all * sel
        omega_h = omega_from_delta(p, topo, I_node, d_all * sel)
        at_h = jnp.asarray(topo.node_stage == h)
        omega = jnp.where(at_h, omega_h, omega)
    return delta, omega


def analytic_gradient(
    p: jnp.ndarray,
    topo: Topology,
    profile: ModelProfile,
    I_node: jnp.ndarray,
    hyper: DtoHyperParams,
) -> jnp.ndarray:
    """dR/dp_ij = (phi_i * I_i / Phi) * Delta_ij (paper Eq. 22), at steady state.

    Used as the oracle in Lemma-1 property tests against jax.grad.
    """
    phi, lam = queueing.steady_state_flows(p, topo, profile, I_node)
    delta, _ = backward_recursion(p, topo, profile, I_node, lam, hyper)
    total_phi = float(topo.phi_ext.sum())
    src = topo.edge_src
    return phi[src] * I_node[src] / total_phi * delta
