"""System utility U(T, A) (paper Eq. 9)."""
from __future__ import annotations


def utility(delay: float, accuracy_normalized: float, a: float) -> float:
    """U = a*T - (1-a) * (A - A_min)/(A_max - A_min).  Lower is better."""
    return a * delay - (1.0 - a) * accuracy_normalized
