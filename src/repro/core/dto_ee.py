"""DTO-EE: distributed joint optimization of task offloading and early-exit
confidence thresholds (paper Algorithms 1-3).

The per-round message passing (DTO-R + DTO-O) is fully vectorized JAX and
jit-compiled once per topology; the discrete threshold moves (Alg. 3 lines
5-8) are host-side table lookups, matching the paper's split between the
continuous offloading update and the discrete threshold grid.

Faithful distributed semantics: arrival estimates (phi) and gradient info
(Omega) each propagate ONE stage per communication round — receivers use the
offloaders' previous-round RURs, offloaders use the receivers' previous-round
Omega (stale by one round), exactly like the RUR/RUS exchange.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import gradients, penalty, queueing
from repro.core.thresholds import ExitProfile, threshold_step
from repro.core.types import DtoHyperParams, ModelProfile, Topology


class RoundCarry(NamedTuple):
    """Traced per-round state of the message passing."""

    p: jnp.ndarray  # [E] offloading probabilities
    phi: jnp.ndarray  # [N] arrival-rate estimates (tasks/s)
    lam: jnp.ndarray  # [N] required compute (GFLOP/s)
    omega: jnp.ndarray  # [N] gradient info from each node's last DTO-O run


@dataclasses.dataclass
class DtoState:
    """Full algorithm state across a configuration-update phase."""

    carry: RoundCarry
    thresholds: np.ndarray  # one per early-exit branch (discrete grid)
    stage_remaining: np.ndarray  # I_h for stages 0..H
    accuracy: float
    round: int = 0


@dataclasses.dataclass
class PhaseResult:
    state: DtoState
    delay_history: np.ndarray
    objective_history: np.ndarray
    accuracy_history: np.ndarray
    rounds_run: int


def clone_state(state: DtoState) -> DtoState:
    """Independent copy for speculative configuration phases (the online
    controller plans against measured topologies without touching the live
    state until the install point).  The carry's jnp arrays are immutable and
    shared; the host-side numpy arrays are copied."""
    return DtoState(
        carry=state.carry,
        thresholds=state.thresholds.copy(),
        stage_remaining=state.stage_remaining.copy(),
        accuracy=state.accuracy,
        round=state.round,
    )


def uniform_strategy(topo: Topology) -> jnp.ndarray:
    """p_{i,j}^0 = 1/|L_i| (Alg. 3 line 1)."""
    deg = np.maximum(topo.out_degree(), 1)
    return jnp.asarray(1.0 / deg[topo.edge_src], jnp.float32)


def eq19_update(
    p: jnp.ndarray, delta: jnp.ndarray, topo: Topology, tau_p: float | jnp.ndarray
) -> jnp.ndarray:
    """The Eq. 19 move: shift tau_p of off-minimum mass onto argmin-Delta.

    p_j   <- (1 - tau_p) p_j          for j != j*
    p_j*  <- p_j* + tau_p sum_{j!=j*} p_j  ==  p_j* + tau_p (1 - p_j*)
    """
    src = topo.edge_src
    n = topo.num_nodes
    e = topo.num_edges
    dmin = jax.ops.segment_min(delta, src, num_segments=n)
    at_min = delta <= dmin[src] + 0.0
    # first-occurrence tie-break for j*
    idx = jnp.where(at_min, jnp.arange(e), e)
    star_idx = jax.ops.segment_min(idx, src, num_segments=n)
    is_star = jnp.arange(e) == star_idx[src]
    p_new = jnp.where(is_star, p + tau_p * (1.0 - p), (1.0 - tau_p) * p)
    # float32 drift guard: renormalize per source
    tot = jax.ops.segment_sum(p_new, src, num_segments=n)
    return p_new / jnp.maximum(tot[src], 1e-12)


def make_round_step(
    topo: Topology, profile: ModelProfile, hyper: DtoHyperParams
) -> Callable[[RoundCarry, jnp.ndarray], tuple[RoundCarry, jnp.ndarray]]:
    """Build the jitted synchronous round: DTO-R (Alg. 1) then DTO-O (Alg. 2).

    Returns fn(carry, I_node) -> (carry', delta).
    """

    @jax.jit
    def round_step(carry: RoundCarry, I_node: jnp.ndarray, tau_p: jnp.ndarray):
        # --- DTO-R: receivers process RURs -> (lam, phi), respond RUS ------
        phi_new, lam_new = queueing.one_round_flows(
            carry.p, carry.phi, topo, profile, I_node
        )
        # --- DTO-O: offloaders process RUSs (stale omega), update strategy -
        delta = gradients.delta_edges(
            carry.p, topo, profile, lam_new, carry.omega, hyper
        )
        omega_new = gradients.omega_from_delta(carry.p, topo, I_node, delta)
        p_new = eq19_update(carry.p, delta, topo, tau_p)
        return RoundCarry(p=p_new, phi=phi_new, lam=lam_new, omega=omega_new), delta

    return round_step


def evaluate_strategy(
    p: jnp.ndarray,
    topo: Topology,
    profile: ModelProfile,
    I_node: jnp.ndarray,
    hyper: DtoHyperParams,
) -> tuple[float, float, bool]:
    """(T, R, stable) at exact steady-state flows — the analytic scoreboard."""
    phi, lam = queueing.steady_state_flows(p, topo, profile, I_node)
    t = queueing.average_response_delay(p, topo, profile, I_node, phi, lam)
    n = penalty.penalty(topo, lam, hyper.penalty_k, hyper.penalty_eps)
    stable = queueing.is_stable(topo, lam)
    return float(t), float(t + n), bool(stable)


def init_state(
    topo: Topology,
    profile: ModelProfile,
    exit_profile: ExitProfile,
    initial_thresholds: np.ndarray | None = None,
    p0: jnp.ndarray | None = None,
) -> DtoState:
    thresholds = (
        np.asarray(initial_thresholds, np.float64)
        if initial_thresholds is not None
        else np.full(exit_profile.num_early_branches, 0.8)
    )
    ev = exit_profile.evaluate(thresholds)
    p = p0 if p0 is not None else uniform_strategy(topo)
    n = topo.num_nodes
    carry = RoundCarry(
        p=p,
        phi=jnp.asarray(topo.phi_ext, jnp.float32),
        lam=jnp.zeros(n, jnp.float32),
        omega=jnp.zeros(n, jnp.float32),
    )
    return DtoState(
        carry=carry,
        thresholds=thresholds,
        stage_remaining=ev.stage_remaining,
        accuracy=ev.accuracy,
    )


def run_configuration_phase(
    topo: Topology,
    profile: ModelProfile,
    exit_profile: ExitProfile,
    hyper: DtoHyperParams,
    state: DtoState | None = None,
    adapt_thresholds: bool = True,
    round_step=None,
    tau_p: float | None = None,
) -> PhaseResult:
    """Algorithm 3: n rounds of concurrent DTO-R/DTO-O; every m rounds, the
    cyclically-selected stage's exit branch tries a +/- tau_c threshold move.

    ``tau_p`` overrides the hyper step size for this phase (solve() decays
    it across phases — Frank-Wolfe-style diminishing steps to converge past
    the O(tau_p) oscillation band of the fixed-step Eq. 19 dynamics)."""
    H = profile.num_stages
    state = state or init_state(topo, profile, exit_profile)
    round_step = round_step or make_round_step(topo, profile, hyper)
    tau_now = jnp.asarray(hyper.tau_p if tau_p is None else tau_p, jnp.float32)

    # branch lookup: stage -> early-branch index
    stage_to_branch = {s: b for b, s in enumerate(exit_profile.branch_stage[:-1])}
    total_phi = float(topo.phi_ext.sum())

    delays, objectives, accuracies = [], [], []
    carry = state.carry
    thresholds = state.thresholds.copy()
    stage_remaining = state.stage_remaining.copy()
    accuracy = state.accuracy

    for t in range(hyper.rounds):
        I_node = jnp.asarray(stage_remaining, jnp.float32)[
            jnp.asarray(topo.node_stage)
        ]
        carry, _delta = round_step(carry, I_node, tau_now)

        # ---- Alg. 3 lines 4-8: cyclic threshold adjustment ----------------
        if adapt_thresholds and t % hyper.threshold_every == 0:
            h = (t // hyper.threshold_every) % H + 1  # 1-indexed stage
            if h in stage_to_branch:
                b = stage_to_branch[h]
                nodes = topo.nodes_at_stage(h)
                phi_np = np.asarray(carry.phi)[nodes]
                omega_np = np.asarray(carry.omega)[nodes]
                decision = threshold_step(
                    exit_profile,
                    thresholds,
                    b,
                    phi_np,
                    omega_np,
                    total_phi,
                    hyper,
                )
                if decision.changed:
                    thresholds = decision.thresholds
                    stage_remaining = decision.stage_remaining
                    accuracy = decision.accuracy

        if (t % 5 == 0) or t == hyper.rounds - 1:
            I_node_now = jnp.asarray(stage_remaining, jnp.float32)[
                jnp.asarray(topo.node_stage)
            ]
            t_now, r_now, _ = evaluate_strategy(
                carry.p, topo, profile, I_node_now, hyper
            )
            delays.append(t_now)
            objectives.append(r_now)
            accuracies.append(accuracy)

    final = DtoState(
        carry=carry,
        thresholds=thresholds,
        stage_remaining=stage_remaining,
        accuracy=accuracy,
        round=state.round + hyper.rounds,
    )
    return PhaseResult(
        state=final,
        delay_history=np.asarray(delays),
        objective_history=np.asarray(objectives),
        accuracy_history=np.asarray(accuracies),
        rounds_run=hyper.rounds,
    )


def solve(
    topo: Topology,
    profile: ModelProfile,
    exit_profile: ExitProfile,
    hyper: DtoHyperParams | None = None,
    max_phases: int = 8,
    tol: float = 1e-4,
    adapt_thresholds: bool = True,
    tau_decay: float = 0.6,
    tau_floor: float = 0.01,
) -> PhaseResult:
    """Run configuration phases until R(P) stops improving (convergence per
    §3.5: R(P^t) is monotone decreasing and bounded below).

    The per-phase step size decays geometrically: the fixed-step Eq. 19
    dynamics oscillate in an O(tau_p) band around the convex optimum
    (the update is a Frank-Wolfe step toward the argmin-Delta vertex), so
    diminishing steps recover convergence to the interior optimum."""
    hyper = hyper or DtoHyperParams()
    round_step = make_round_step(topo, profile, hyper)
    state = None
    last: PhaseResult | None = None
    prev_obj = np.inf
    tau = hyper.tau_p
    for _ in range(max_phases):
        last = run_configuration_phase(
            topo,
            profile,
            exit_profile,
            hyper,
            state=state,
            adapt_thresholds=adapt_thresholds,
            round_step=round_step,
            tau_p=tau,
        )
        state = last.state
        obj = float(last.objective_history[-1])
        # stop only once the step size has annealed AND progress stalled —
        # fixed-tau oscillation would otherwise trigger a premature break
        if tau <= tau_floor and abs(prev_obj - obj) <= tol * max(abs(prev_obj), 1.0):
            break
        prev_obj = obj
        tau = max(tau * tau_decay, tau_floor)
    assert last is not None
    return last
