"""M/D/1-PS queueing model of the staged edge network (paper §2.3-§2.4).

All functions are pure JAX (jit-compatible); the topology's integer arrays
are static (closed over / passed as numpy), probabilities and rates are
traced.  Node-indexed remaining ratios ``I_node[v]`` carry the per-stage
remaining ratio I_h of v's stage (EDs: 1.0).
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.types import ModelProfile, Topology

# A delay stand-in for an unstable queue (lambda >= mu).  Finite so that
# gradients stay well-defined; the exterior penalty term is what actually
# steers the optimizer out of the infeasible region.
UNSTABLE_DELAY = 1e6


def node_remaining_ratio(topo: Topology, stage_remaining: jnp.ndarray) -> jnp.ndarray:
    """Broadcast per-stage remaining ratios I_h to nodes.

    ``stage_remaining`` has length H+1 indexed by stage (entry 0 == 1.0 for
    EDs; entry h == I_h).
    """
    return stage_remaining[topo.node_stage]


def alpha_per_node(topo: Topology, profile: ModelProfile) -> np.ndarray:
    """alpha_h of each node's sub-model (EDs: 0 — they do not compute)."""
    alpha = np.concatenate([[0.0], np.asarray(profile.alpha, np.float64)])
    return alpha[topo.node_stage]


def beta_per_edge(topo: Topology, profile: ModelProfile) -> np.ndarray:
    """beta of the data shipped over each edge == input size of the dst stage."""
    beta = np.concatenate([[0.0], np.asarray(profile.beta, np.float64)])
    return beta[topo.node_stage[topo.edge_dst]]


def steady_state_flows(
    p: jnp.ndarray,
    topo: Topology,
    profile: ModelProfile,
    I_node: jnp.ndarray,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Exact steady-state (phi, lam) via stage-by-stage propagation (Eqs. 3, 5).

    Returns:
      phi[N]: task arrival rate per node (tasks/s).
      lam[N]: required computing resources per node (GFLOP/s), phi * alpha.
    """
    H = topo.num_stages
    alpha_n = jnp.asarray(alpha_per_node(topo, profile), jnp.float32)
    phi = jnp.asarray(topo.phi_ext, jnp.float32)
    src, dst = topo.edge_src, topo.edge_dst
    src_stage = topo.node_stage[src]  # static numpy
    for h in range(0, H):  # propagate across the h -> h+1 boundary
        sel = jnp.asarray((src_stage == h).astype(np.float32))
        contrib = p * phi[src] * I_node[src] * sel
        inflow = jax.ops.segment_sum(contrib, dst, num_segments=topo.num_nodes)
        phi = jnp.where(jnp.asarray(topo.node_stage == h + 1), inflow, phi)
    lam = phi * alpha_n
    return phi, lam


def one_round_flows(
    p: jnp.ndarray,
    phi_prev: jnp.ndarray,
    topo: Topology,
    profile: ModelProfile,
    I_node: jnp.ndarray,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """One synchronous RUR sweep: receivers recompute (phi, lam) from the
    offloaders' *previous-round* arrival estimates (Alg. 1 lines 1-4).

    This is the faithful distributed semantics — arrival information
    propagates one stage per communication round.
    """
    alpha_n = jnp.asarray(alpha_per_node(topo, profile), jnp.float32)
    src, dst = topo.edge_src, topo.edge_dst
    contrib = p * phi_prev[src] * I_node[src]
    inflow = jax.ops.segment_sum(contrib, dst, num_segments=topo.num_nodes)
    is_es = jnp.asarray(topo.node_stage > 0)
    phi = jnp.where(is_es, inflow, jnp.asarray(topo.phi_ext, jnp.float32))
    lam = phi * alpha_n
    return phi, lam


def compute_delay_per_node(topo: Topology, profile: ModelProfile, lam: jnp.ndarray) -> jnp.ndarray:
    """M/D/1-PS sojourn time per subtask on each ES (Eq. 6): alpha/(mu-lam)."""
    alpha_n = jnp.asarray(alpha_per_node(topo, profile), jnp.float32)
    mu = jnp.asarray(np.where(np.isinf(topo.mu), 1e30, topo.mu), jnp.float32)
    gap = mu - lam
    stable = gap > 0
    delay = jnp.where(stable, alpha_n / jnp.where(stable, gap, 1.0), UNSTABLE_DELAY)
    return jnp.where(jnp.asarray(topo.node_stage > 0), delay, 0.0)


def transmission_delay_per_edge(topo: Topology, profile: ModelProfile) -> np.ndarray:
    """T^cm per edge (Eq. 4): beta_{h+1} / r_{i,j}.  Static given the topology."""
    return beta_per_edge(topo, profile) / topo.edge_rate


def average_response_delay(
    p: jnp.ndarray,
    topo: Topology,
    profile: ModelProfile,
    I_node: jnp.ndarray,
    phi: jnp.ndarray,
    lam: jnp.ndarray,
) -> jnp.ndarray:
    """System mean response delay T (Eq. 8).

    T = (1/Phi) * sum_j [ lam_j/(mu_j - lam_j) + sum_{i in V_j} phi_ij * T^cm_ij ]
    """
    mu = jnp.asarray(np.where(np.isinf(topo.mu), 1e30, topo.mu), jnp.float32)
    gap = mu - lam
    stable = gap > 0
    queue_term = jnp.where(stable, lam / jnp.where(stable, gap, 1.0), lam * UNSTABLE_DELAY)
    queue_term = jnp.where(jnp.asarray(topo.node_stage > 0), queue_term, 0.0)

    t_cm = jnp.asarray(transmission_delay_per_edge(topo, profile), jnp.float32)
    phi_edge = p * phi[topo.edge_src] * I_node[topo.edge_src]
    total_phi = jnp.asarray(topo.phi_ext.sum(), jnp.float32)
    return (jnp.sum(queue_term) + jnp.sum(phi_edge * t_cm)) / total_phi


def is_stable(topo: Topology, lam: jnp.ndarray, eps: float = 0.0) -> jnp.ndarray:
    """True iff every ES satisfies lam < mu - eps (P1's first constraint)."""
    mu = jnp.asarray(np.where(np.isinf(topo.mu), 1e30, topo.mu), jnp.float32)
    ok = lam < mu - eps
    return jnp.all(jnp.where(jnp.asarray(topo.node_stage > 0), ok, True))


def system_utilization(topo: Topology, lam: jnp.ndarray) -> jnp.ndarray:
    """max_j lam_j / mu_j over ESs — headline congestion metric."""
    mu = jnp.asarray(np.where(np.isinf(topo.mu), 1e30, topo.mu), jnp.float32)
    rho = lam / mu
    return jnp.max(jnp.where(jnp.asarray(topo.node_stage > 0), rho, 0.0))
