"""Discrete-event simulator of the collaborative-inference edge network.

This is the measurement side of the paper's evaluation (§4): tasks arrive at
EDs as Poisson processes, are routed hop-by-hop per the offloading strategy
P, receive deterministic service (alpha_h GFLOPs) at each ES under
**processor sharing** (the M/D/1-PS model of Eq. 6), and may exit early when
their branch confidence clears the threshold.  Response delay is measured
per task from ED arrival to exit; accuracy comes from the same recorded
validation outputs the accuracy-ratio table uses, so the analytic optimizer
and the simulator agree on what a threshold does.

Implementation: a heap event loop with versioned completion events (PS
queues reschedule their earliest completion whenever membership changes).
Python-level, but task counts are O(1e4) per slot — milliseconds to run.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools

import numpy as np

from repro.core.thresholds import ExitProfile
from repro.core.types import ModelProfile, Topology


@dataclasses.dataclass
class SimResult:
    mean_delay: float
    p95_delay: float
    accuracy: float
    completed: int
    generated: int
    exit_fraction: np.ndarray  # per branch (early branches..., final)
    mean_delay_per_stage: np.ndarray  # diagnostic: time spent per stage index


class _PSQueue:
    """Single-server processor-sharing queue with deterministic job sizes.

    Membership lives in flat numpy arrays (``_ids`` / ``_rem``, swap-remove
    on departure) so ``advance`` — the simulator's hot loop, called on every
    event touching the queue — is one vectorized subtraction instead of a
    per-job Python dict walk, and finished jobs are harvested in one
    ``pop_done`` mask rather than a per-item scan.
    """

    __slots__ = ("mu", "t", "version", "_ids", "_rem", "_slot", "_n", "_min_slot")

    def __init__(self, mu: float, capacity: int = 64):
        self.mu = mu
        self.t = 0.0
        self.version = 0
        self._ids = np.empty(capacity, np.int64)
        self._rem = np.empty(capacity, np.float64)
        self._slot: dict[int, int] = {}  # job id -> slot in the arrays
        self._n = 0
        # cached argmin slot (-1 = unknown).  PS decrements are uniform, so
        # the ordering of remaining works only changes on add/remove — adds
        # update the cache in O(1) and next_completion avoids an O(n) scan
        # per event.
        self._min_slot = -1

    def __len__(self) -> int:
        return self._n

    def advance(self, now: float) -> None:
        if self._n:
            self._rem[: self._n] -= self.mu / self._n * (now - self.t)
        self.t = now

    def add(self, now: float, job: int, work: float) -> None:
        self.advance(now)
        if self._n == self._ids.shape[0]:
            self._ids = np.concatenate([self._ids, np.empty_like(self._ids)])
            self._rem = np.concatenate([self._rem, np.empty_like(self._rem)])
        slot = self._n
        self._ids[slot] = job
        self._rem[slot] = work
        self._slot[job] = slot
        self._n += 1
        if self._min_slot >= 0 and work < self._rem[self._min_slot]:
            self._min_slot = slot
        self.version += 1

    def _drop_slot(self, slot: int) -> None:
        last = self._n - 1
        if self._min_slot == slot:
            self._min_slot = -1
        elif self._min_slot == last:
            self._min_slot = slot
        if slot != last:
            self._ids[slot] = self._ids[last]
            self._rem[slot] = self._rem[last]
            self._slot[int(self._ids[slot])] = slot
        self._n = last

    def remove(self, now: float, job: int) -> None:
        self.advance(now)
        slot = self._slot.pop(job, None)
        if slot is None:
            return
        self._drop_slot(slot)
        self.version += 1

    def pop_done(self, eps: float = 1e-12) -> list[int]:
        """Remove and return every job with no remaining work (one mask scan,
        then swap-remove per finished job — descending so slots stay valid)."""
        n = self._n
        if not n:
            return []
        idx = np.nonzero(self._rem[:n] <= eps)[0]
        if not idx.size:
            return []
        done = []
        for slot in idx[::-1].tolist():
            j = int(self._ids[slot])
            done.append(j)
            del self._slot[j]
            self._drop_slot(slot)
        self.version += 1
        return done

    def pop_overdue(self, now: float) -> list[int]:
        """Force-complete the earliest job if its completion time is <= now.

        Floating-point residue can leave a finished job's remaining work a
        hair above the ``pop_done`` eps while its completion event has
        already fired; without this the candidate event re-schedules itself
        at a frozen clock and the event loop livelocks.
        """
        nxt = self.next_completion()
        if nxt is None or nxt[0] > now:
            return []
        job = nxt[1]
        self._drop_slot(self._slot.pop(job))
        self.version += 1
        return [job]

    def next_completion(self) -> tuple[float, int] | None:
        if not self._n:
            return None
        if self._min_slot < 0:
            self._min_slot = int(np.argmin(self._rem[: self._n]))
        i = self._min_slot
        return (
            self.t + max(float(self._rem[i]), 0.0) * self._n / self.mu,
            int(self._ids[i]),
        )


@dataclasses.dataclass
class _Task:
    tid: int
    arrival: float
    record: int  # row in the exit profile's validation record
    stage: int = 0  # stage of the node it currently sits on / travels to
    node: int = -1
    t_enter_stage: float = 0.0


class RoutingCdf:
    """Per-strategy cache of the routing CDF over every node's out-edges.

    Successor sampling is one inverse-CDF draw (``searchsorted`` into the
    node's precomputed cumsum slice) instead of an ``rng.choice(p=...)``
    call — the simulator samples once per task-hop, so this is hot.
    """

    def __init__(self, topo: Topology, p: np.ndarray):
        self.topo = topo
        self.cdf = np.cumsum(np.asarray(p, np.float64))
        # per-node total mass: cdf[hi-1] - (cdf[lo-1] if lo else 0)
        off = topo.edge_offsets

        def _at(i: int) -> float:
            return float(self.cdf[i - 1]) if i > 0 else 0.0

        self.lo_mass = np.array([_at(int(o)) for o in off[:-1]])
        self.hi_mass = np.array([_at(int(o)) for o in off[1:]])

    def sample(self, rng: np.random.Generator, node: int) -> tuple[int, int]:
        topo = self.topo
        lo, hi = int(topo.edge_offsets[node]), int(topo.edge_offsets[node + 1])
        m_lo, m_hi = self.lo_mass[node], self.hi_mass[node]
        if m_hi - m_lo <= 0:
            e = int(rng.integers(lo, hi))
        else:
            r = m_lo + rng.random() * (m_hi - m_lo)
            e = min(int(np.searchsorted(self.cdf[lo:hi], r, side="right")) + lo, hi - 1)
        return int(topo.edge_dst[e]), e


def simulate_slot(
    topo: Topology,
    profile: ModelProfile,
    exit_profile: ExitProfile,
    p: np.ndarray,
    thresholds: np.ndarray,
    duration: float = 5.0,
    seed: int = 0,
    warmup: float = 0.5,
    strategy_switch: tuple[float, np.ndarray] | None = None,
    coalesce: bool = True,
    tracer=None,
) -> SimResult:
    """Simulate one task-offloading phase of ``duration`` seconds.

    ``strategy_switch = (t_ready, p_old)``: before ``t_ready`` (the
    algorithm's decision time) routing uses ``p_old`` — this is how the
    dynamic-environment experiment charges NGTO/GA for their slow decisions.

    Tasks still in flight at the slot end are dropped from the delay average
    (the paper measures completed samples only).

    ``coalesce`` harvests every event sharing the popped timestamp in one
    gulp (processing order — heap order at equal times — is unchanged, so
    results are identical); ``False`` keeps the one-pop-per-iteration loop
    for A/B measurement.

    ``tracer`` (a :class:`repro.obs.trace.SpanTracer`) receives one span
    tree per task with SIMULATED timestamps injected at each event — the
    simulator has no clock of its own beyond the heap, so span times are the
    exact event floats.  PS service is one ``compute`` span per hop
    (``ps=True``: processor sharing interleaves, so the sojourn is not
    separable into wait + service); transfers and retirements mirror the
    serving engine's vocabulary.  ``None`` skips every emission.
    """
    rng = np.random.default_rng(seed)
    p = np.asarray(p, np.float64)
    H = profile.num_stages
    thresholds = np.asarray(thresholds, np.float64)
    n_records = exit_profile.conf.shape[0]
    # stage (1-indexed) -> early-branch index
    stage_to_branch = {s: b for b, s in enumerate(exit_profile.branch_stage[:-1])}

    queues = {
        int(v): _PSQueue(float(topo.mu[v]))
        for v in range(topo.num_nodes)
        if topo.node_stage[v] > 0
    }

    # --- seed arrival events -----------------------------------------------
    # heap entries: (time, seq, kind, payload)
    #   kind 0: task arrives at an ED            payload: ed
    #   kind 1: transfer completes, join queue   payload: (task, node)
    #   kind 2: PS completion candidate          payload: (node, version)
    heap: list = []
    seq = itertools.count()
    for ed in topo.nodes_at_stage(0):
        rate = float(topo.phi_ext[ed])
        if rate <= 0:
            continue
        t = rng.exponential(1.0 / rate)
        while t < duration:
            heapq.heappush(heap, (t, next(seq), 0, int(ed)))
            t += rng.exponential(1.0 / rate)

    tasks: dict[int, _Task] = {}
    tid_counter = itertools.count()
    delays: list[float] = []
    correct_flags: list[bool] = []
    exit_counts = np.zeros(len(exit_profile.branch_stage), np.int64)
    stage_time = np.zeros(H + 1, np.float64)
    generated = 0

    route_cdf = RoutingCdf(topo, p)
    route_cdf_old = (
        RoutingCdf(topo, strategy_switch[1]) if strategy_switch is not None else None
    )

    def routing(now: float) -> RoutingCdf:
        if strategy_switch is not None and now < strategy_switch[0]:
            return route_cdf_old
        return route_cdf

    def schedule_completion(now: float, node: int) -> None:
        q = queues[node]
        nxt = q.next_completion()
        if nxt is not None:
            heapq.heappush(heap, (nxt[0], next(seq), 2, (node, q.version)))

    def depart(now: float, task: _Task, node: int) -> None:
        """Service done at ``node`` (stage h): exit early or offload onward."""
        h = int(topo.node_stage[node])
        stage_time[h] += now - task.t_enter_stage
        b = stage_to_branch.get(h)
        exits_here = False
        if b is not None:
            exits_here = exit_profile.conf[task.record, b] >= thresholds[b]
        if tracer is not None:
            tracer.add_span(
                task.tid, "compute", task.t_enter_stage, now, node=node,
                stage=h, ps=True,
            )
        if h == H or exits_here:
            delays.append(now - task.arrival)
            branch = b if (exits_here and h < H) else len(exit_counts) - 1
            exit_counts[branch] += 1
            correct_flags.append(bool(exit_profile.correct[task.record, branch]))
            tasks.pop(task.tid, None)
            if tracer is not None:
                tracer.on_exit(
                    now, task.tid, h,
                    float(exit_profile.conf[task.record, branch]),
                )
            return
        send(now, task, node)

    def send(now: float, task: _Task, node: int) -> None:
        """Offload from ``node`` to a sampled successor (transmission hop)."""
        nxt, e = routing(now).sample(rng, node)
        h_next = int(topo.node_stage[nxt])
        beta = profile.beta[h_next - 1]
        t_cm = beta / float(topo.edge_rate[e])
        task.stage = h_next
        task.node = nxt
        if tracer is not None:
            tracer.on_transfer(now, now + t_cm, t_cm, node, nxt, task.tid, beta)
        heapq.heappush(heap, (now + t_cm, next(seq), 1, (task.tid, nxt)))

    # Arrivals stop at ``duration``; queues then drain so every generated
    # task is measured (the paper averages completed samples).  The horizon
    # only guards against a pathologically unstable configuration.
    horizon = duration * 20.0
    batch: list = []
    while heap:
        now, _, kind, payload = heapq.heappop(heap)
        if now > horizon:
            break
        batch.clear()
        batch.append((kind, payload))
        if coalesce:
            # Same-timestamp harvest: drain every event already queued at
            # ``now`` in one pop burst.  Heap order at equal times is seq
            # order, and a handler pushing a new event at ``now`` gets a
            # larger seq than anything queued — so the processing order is
            # exactly the one-pop-per-iteration loop's, with one outer-loop
            # pass (horizon check, tuple unpack) per timestamp instead of
            # per event.
            while heap and heap[0][0] == now:
                _, _, k, pl = heapq.heappop(heap)
                batch.append((k, pl))
        for kind, payload in batch:
            if kind == 0:
                ed = payload
                task = _Task(
                    tid=next(tid_counter),
                    arrival=now,
                    record=int(rng.integers(0, n_records)),
                )
                generated += 1
                tasks[task.tid] = task
                if tracer is not None:
                    # sim-time clock injection: the tracer's SimClock follows
                    # the heap's event floats, not wall time
                    tracer.on_submit(now, task.tid, int(ed), now)
                send(now, task, ed)
            elif kind == 1:
                tid, node = payload
                task = tasks.get(tid)
                if task is None:
                    continue
                task.t_enter_stage = now
                q = queues[node]
                work = profile.alpha[int(topo.node_stage[node]) - 1]
                q.add(now, tid, work)
                schedule_completion(now, node)
            else:  # kind == 2: completion candidate
                node, version = payload
                q = queues[node]
                if version != q.version:
                    continue  # stale
                q.advance(now)
                done = q.pop_done()
                if not done:
                    done = q.pop_overdue(now)
                schedule_completion(now, node)
                for j in done:
                    task = tasks.get(j)
                    if task is not None:
                        depart(now, task, node)

    delays_a = np.asarray(delays)
    keep = delays_a if warmup <= 0 else delays_a  # all completions counted
    mean_delay = float(keep.mean()) if keep.size else float("inf")
    p95 = float(np.percentile(keep, 95)) if keep.size else float("inf")
    acc = float(np.mean(correct_flags)) if correct_flags else 0.0
    total_exits = max(exit_counts.sum(), 1)
    return SimResult(
        mean_delay=mean_delay,
        p95_delay=p95,
        accuracy=acc,
        completed=int(keep.size),
        generated=generated,
        exit_fraction=exit_counts / total_exits,
        mean_delay_per_stage=stage_time / max(len(delays), 1),
    )
