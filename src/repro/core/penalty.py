"""Exterior-point penalty (paper Eq. 11) and the penalized objective R(P)."""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from repro.core import queueing
from repro.core.types import DtoHyperParams, ModelProfile, Topology


def penalty(
    topo: Topology,
    lam: jnp.ndarray,
    k: float,
    eps: float,
) -> jnp.ndarray:
    """N(P) = K * sum_j max(0, lam_j - mu_j + eps)^2  over ESs (Eq. 11)."""
    mu = jnp.asarray(np.where(np.isinf(topo.mu), 1e30, topo.mu), jnp.float32)
    viol = jnp.maximum(0.0, lam - mu + eps)
    viol = jnp.where(jnp.asarray(topo.node_stage > 0), viol, 0.0)
    return k * jnp.sum(viol**2)


def objective_r(
    p: jnp.ndarray,
    topo: Topology,
    profile: ModelProfile,
    I_node: jnp.ndarray,
    hyper: DtoHyperParams,
) -> jnp.ndarray:
    """R(P) = T + N(P) at exact steady-state flows (problem P2)."""
    phi, lam = queueing.steady_state_flows(p, topo, profile, I_node)
    t = queueing.average_response_delay(p, topo, profile, I_node, phi, lam)
    n = penalty(topo, lam, hyper.penalty_k, hyper.penalty_eps)
    return t + n
