"""Bridge ArchConfig -> ModelProfile: per-stage cost/accuracy profiles.

The paper drives its queueing layer from profiled per-stage costs (Table 2).
For the assigned architectures we derive the same quantities analytically:

  alpha_h : GFLOPs to run stage h for one request (2 * params_h * tokens,
            plus the attention term) — the forward-pass cost the ES pays.
  beta_h  : MB shipped into stage h — the residual stream (tokens x d_model
            x 2 bytes) for h > 1, token ids for h = 1.
  A_h     : branch accuracy — anchored to the paper's BERT branch curve,
            scaled into (floor, ceiling) by relative depth (synthetic; the
            engine's real exit decisions use live model confidences).
"""
from __future__ import annotations

import numpy as np

from repro.configs.base import ArchConfig
from repro.core.types import ModelProfile
from repro.models import moe as moe_lib


def stage_param_counts(cfg: ArchConfig) -> list[int]:
    """Approximate active parameters per stage (MoE counts top-k experts)."""
    d = cfg.d_model
    per_block: dict[str, int] = {}
    for kind in set(cfg.period):
        if kind in ("attn", "dense_attn", "moe_attn"):
            if cfg.mla is not None:
                m = cfg.mla
                attn = d * m.num_heads * m.qk_head_dim + d * (
                    m.kv_lora_rank + m.qk_rope_head_dim
                )
                attn += m.kv_lora_rank * m.num_heads * (
                    m.qk_nope_head_dim + m.v_head_dim
                )
                attn += m.num_heads * m.v_head_dim * d
            else:
                a = cfg.attn_dims()
                attn = d * a.q_dim + 2 * d * a.kv_dim + a.q_dim * d
            if kind == "moe_attn":
                ffn = moe_lib.moe_active_params(cfg.moe)
            elif cfg.ffn == "mlp":
                ffn = 2 * d * cfg.d_ff
            else:
                ffn = 3 * d * cfg.d_ff
            per_block[kind] = attn + ffn
        elif kind == "mamba":
            m = cfg.mamba
            per_block[kind] = d * 2 * m.d_inner + m.d_inner * d + d * m.conv_dim
        elif kind in ("mlstm", "slstm"):
            x = cfg.xlstm
            per_block[kind] = 6 * d * d  # projections + gates, coarse
    sizes = []
    for n_periods in cfg.stage_periods():
        sizes.append(n_periods * sum(per_block[k] for k in cfg.period))
    return sizes


def profile_from_arch(
    cfg: ArchConfig,
    tokens_per_task: int = 128,
    acc_floor: float = 0.45,
    acc_ceiling: float = 0.75,
) -> ModelProfile:
    params_per_stage = stage_param_counts(cfg)
    alpha = tuple(2.0 * p * tokens_per_task / 1e9 for p in params_per_stage)
    beta_hidden = tokens_per_task * cfg.d_model * 2 / 1e6  # bf16 residuals, MB
    beta = (tokens_per_task * 4 / 1e6,) + (beta_hidden,) * (cfg.num_stages - 1)
    has_exit = tuple(
        (h + 1) in cfg.exit_stages for h in range(cfg.num_stages - 1)
    ) + (False,)
    depth = np.cumsum(cfg.stage_periods()) / sum(cfg.stage_periods())
    acc = acc_floor + (acc_ceiling - acc_floor) * np.sqrt(depth)
    branch_acc = tuple(
        float(acc[h]) if (h + 1 in cfg.exit_stages or h == cfg.num_stages - 1) else 0.0
        for h in range(cfg.num_stages)
    )
    return ModelProfile(
        name=cfg.name,
        alpha=alpha,
        beta=beta,
        has_exit=has_exit,
        branch_accuracy=branch_acc,
    )
