"""Paged KV-cache bookkeeping: the host-side ``BlockAllocator``.

The paged slot store splits a replica's KV memory into fixed-size *blocks*
(``[n_periods, num_blocks, block_size, ...]`` pool leaves on device); each
in-flight sequence owns an ordered *block table* mapping its logical blocks
(position ``p`` lives in logical block ``p // block_size``) to physical pool
rows.  This module is the pure-Python control plane for that layout:

  * **refcounted allocation** — a physical block may back several sequences
    (prompt-prefix sharing / fork); it returns to the free list only when the
    last reference drops.
  * **prompt-prefix sharing** — ``alloc`` content-hashes each *full* block of
    the prompt (chained ``(parent_block, tokens)`` keys, so equal keys imply
    equal prefixes) and reuses a live block with identical content instead of
    allocating + rewriting it.  Only full blocks strictly inside the prompt
    are shared, so the first decode write of a sequence always lands in an
    exclusively-owned block.
  * **copy-on-write** — ``append`` into a block shared with another sequence
    (possible after ``fork``) first moves the writer onto a private copy and
    reports the ``(src, dst)`` pair so the caller can copy the device block.
  * **reuse before growth** — previously-freed blocks are handed out before
    never-used ones, so a long-running replica's footprint is its high-water
    mark, not its allocation count.

The allocator never touches device memory; the serving engine turns its
decisions into block-table arrays for the paged gather/scatter/decode
programs in ``repro.serving.steps``.  Hypothesis property tests
(``tests/test_paging_properties.py``) drive random alloc/fork/append/free
schedules against a shadow model of these invariants.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Sequence


def blocks_for(n_tokens: int, block_size: int) -> int:
    """Number of blocks covering ``n_tokens`` positions."""
    return -(-n_tokens // block_size)


@dataclasses.dataclass
class AllocResult:
    handle: int
    table: list[int]  # physical block per logical block
    shared: list[bool]  # True where the block was reused from the prefix map
    new_blocks: list[int]  # blocks this call took from the pool


@dataclasses.dataclass
class AppendResult:
    block: int  # physical block the new token's position lives in
    offset: int  # position within that block
    new_block: bool  # the append crossed into a freshly-allocated block
    cow: tuple[int, int] | None  # (src, dst) if a shared block was copied


class BlockAllocator:
    """Refcounted block pool with prefix sharing and copy-on-write.

    Physical blocks are ids ``0 .. num_blocks - 1``; the device pool usually
    reserves one extra trailing row as the trash block for padded batch rows,
    which this allocator never sees.
    """

    def __init__(self, num_blocks: int, block_size: int, prefix_sharing: bool = True):
        if num_blocks < 1:
            raise ValueError("num_blocks must be >= 1")
        if block_size < 1:
            raise ValueError("block_size must be >= 1")
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.prefix_sharing = prefix_sharing
        self._ref = [0] * num_blocks
        self._free: deque[int] = deque()  # previously used, now free
        self._fresh = 0  # next never-used block id
        self._prefix_to_block: dict = {}  # chain key -> block id
        self._block_prefix: dict[int, object] = {}  # block id -> chain key
        self._tables: dict[int, list[int]] = {}  # handle -> block table
        self._lengths: dict[int, int] = {}  # handle -> tokens written
        self._next_handle = 0

    # -- pool accounting ----------------------------------------------------
    @property
    def free_blocks(self) -> int:
        return len(self._free) + (self.num_blocks - self._fresh)

    @property
    def used_blocks(self) -> int:
        return self.num_blocks - self.free_blocks

    @property
    def used_fraction(self) -> float:
        return self.used_blocks / self.num_blocks

    def occupancy_stats(self) -> dict:
        """Pool-accounting snapshot for the instrumentation stream /
        metrics registry (JSON-able, O(sequences))."""
        shared = sum(1 for r in self._ref if r > 1)
        return {
            "num_blocks": self.num_blocks,
            "used_blocks": self.used_blocks,
            "free_blocks": self.free_blocks,
            "used_fraction": self.used_fraction,
            "shared_blocks": shared,
            "live_sequences": len(self._tables),
        }

    def refcount(self, block: int) -> int:
        return self._ref[block]

    def table(self, handle: int) -> list[int]:
        return list(self._tables[handle])

    def length(self, handle: int) -> int:
        return self._lengths[handle]

    def blocks_needed(self, n_tokens: int) -> int:
        """Worst-case (sharing-blind) blocks a prompt of ``n_tokens`` needs —
        the conservative admission-gating bound."""
        return blocks_for(n_tokens, self.block_size)

    # -- internals ----------------------------------------------------------
    def _take_block(self) -> int | None:
        """Freed blocks are reused before never-used ones ("pool growth")."""
        if self._free:
            b = self._free.popleft()
        elif self._fresh < self.num_blocks:
            b = self._fresh
            self._fresh += 1
        else:
            return None
        assert self._ref[b] == 0, f"block {b} on free path with refcount {self._ref[b]}"
        self._ref[b] = 1
        return b

    def _release_block(self, block: int) -> None:
        self._ref[block] -= 1
        if self._ref[block] < 0:
            raise ValueError(f"block {block} double-freed")
        if self._ref[block] == 0:
            key = self._block_prefix.pop(block, None)
            if key is not None and self._prefix_to_block.get(key) == block:
                del self._prefix_to_block[key]
            self._free.append(block)

    # -- sequence lifecycle -------------------------------------------------
    def alloc(self, tokens: Sequence[int]) -> AllocResult | None:
        """Admit a prompt: blocks for every position of ``tokens``.

        Full blocks whose chained content key matches a live block are shared
        (refcount bump, caller must NOT write them); the partial tail block —
        and every block when sharing is off — is freshly owned.  Returns
        ``None`` (no state change) if the pool can't cover the unshared part.
        """
        n_tokens = len(tokens)
        if n_tokens < 1:
            raise ValueError("cannot allocate an empty sequence")
        bs = self.block_size
        n_logical = blocks_for(n_tokens, bs)
        n_full = n_tokens // bs

        # resolve sharing first (no mutation), then check capacity, then commit
        plan: list[tuple[int | None, tuple | None]] = []  # (shared block, tokens)
        parent: int | None = None
        chain_broken = False
        for j in range(n_logical):
            block_toks = None
            shared: int | None = None
            if self.prefix_sharing and j < n_full:
                block_toks = tuple(int(t) for t in tokens[j * bs : (j + 1) * bs])
                if not chain_broken:
                    shared = self._prefix_to_block.get((parent, block_toks))
                    if shared is None:
                        chain_broken = True  # a dead chain can't extend
                    else:
                        parent = shared
            plan.append((shared, block_toks))
        n_new = sum(1 for shared, _ in plan if shared is None)
        if n_new > self.free_blocks:
            return None

        table: list[int] = []
        shared_mask: list[bool] = []
        new_blocks: list[int] = []
        parent = None
        for shared, block_toks in plan:
            if shared is not None:
                self._ref[shared] += 1
                table.append(shared)
                shared_mask.append(True)
                parent = shared
                continue
            b = self._take_block()
            assert b is not None  # capacity checked above
            if block_toks is not None:
                # register even past the first miss — keyed by the ACTUAL
                # parent, so a later identical prompt can share this block
                key = (parent, block_toks)
                self._prefix_to_block[key] = b
                self._block_prefix[b] = key
                parent = b
            else:
                parent = None
            table.append(b)
            shared_mask.append(False)
            new_blocks.append(b)
        handle = self._next_handle
        self._next_handle += 1
        self._tables[handle] = table
        self._lengths[handle] = n_tokens
        return AllocResult(handle, list(table), shared_mask, new_blocks)

    def fork(self, handle: int) -> int:
        """Share every block of ``handle`` with a new sequence (zero-copy)."""
        table = self._tables[handle]
        for b in table:
            self._ref[b] += 1
        new = self._next_handle
        self._next_handle += 1
        self._tables[new] = list(table)
        self._lengths[new] = self._lengths[handle]
        return new

    def append_cost(self, handle: int) -> int:
        """Pool blocks the next ``append(handle)`` will consume (0 or 1:
        crossing a block boundary or copy-on-write takes one) — lets a
        scheduler budget a batch of appends against ``free_blocks``."""
        pos = self._lengths[handle]
        logical = pos // self.block_size
        if logical >= len(self._tables[handle]):
            return 1  # new block
        if self._ref[self._tables[handle][logical]] > 1:
            return 1  # copy-on-write
        return 0

    def can_append(self, handle: int) -> bool:
        """Whether ``append(handle)`` would succeed right now."""
        return self.append_cost(handle) <= self.free_blocks

    def append(self, handle: int) -> AppendResult | None:
        """Extend ``handle`` by one position; the caller then writes the
        token at ``(block, offset)``.  Allocates a block at block boundaries
        and copies-on-write when the target block is shared; returns ``None``
        (no state change) if the pool is exhausted."""
        table = self._tables[handle]
        pos = self._lengths[handle]
        logical, offset = divmod(pos, self.block_size)
        cow = None
        if logical >= len(table):
            b = self._take_block()
            if b is None:
                return None
            table.append(b)
            new_block = True
        else:
            b = table[logical]
            new_block = False
            if self._ref[b] > 1:
                # copy-on-write: never mutate a block another sequence reads
                dst = self._take_block()
                if dst is None:
                    return None
                self._ref[b] -= 1  # still > 0: the other holders keep it
                table[logical] = dst
                cow = (b, dst)
                b = dst
        self._lengths[handle] = pos + 1
        return AppendResult(b, offset, new_block, cow)

    def free(self, handle: int) -> None:
        """Retire a sequence; blocks with no remaining references return to
        the free list.  Freeing an unknown/already-freed handle raises."""
        table = self._tables.pop(handle, None)
        if table is None:
            raise ValueError(f"sequence handle {handle} not live (double free?)")
        del self._lengths[handle]
        for b in table:
            self._release_block(b)

    # -- introspection for tests --------------------------------------------
    def live_handles(self) -> list[int]:
        return list(self._tables)

    def refcounts(self) -> list[int]:
        return list(self._ref)
