"""Collaborative serving engine: the paper's system with a real model inside.

A model is partitioned into ``cfg.num_stages`` stages; each stage ``h`` is
served by ``n_h`` replica groups (on a real cluster: mesh slices; here:
logical replicas with Jetson-profiled service rates).  The engine:

  * routes each request hop-by-hop by sampling the DTO-EE offloading
    strategy ``p`` (the control plane runs the genuine RUR/RUS rounds on a
    Topology mirroring the replica layout);
  * runs the REAL stage forward for the data plane — the residual stream is
    handed replica-to-replica, and exit decisions use the model's actual
    branch confidences against the thresholds C (not a table);
  * advances a simulated clock with M/D/1-PS service at each replica, so
    measured delays follow the same queueing physics the optimizer models.

This is deliberately a single-process, event-stepped engine: the
distributed *semantics* (who talks to whom, what information each node has)
are faithful; only the transport is in-process.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Any

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import dto_ee
from repro.core.thresholds import ExitProfile
from repro.core.types import DtoHyperParams, ModelProfile, Topology
from repro.models import layers, model as model_lib
from repro.serving.batching import Request
from repro.sharding import constrain


# ---------------------------------------------------------------------------
# Stage programs: jit once per (stage, batch_size)
# ---------------------------------------------------------------------------


class StagePrograms:
    """Compiled per-stage forwards of a partitioned model."""

    def __init__(self, params: Any, cfg: ArchConfig):
        self.cfg = cfg
        self.params = params
        self._fwd = {}

    def run_stage(self, stage_idx: int, x: jnp.ndarray) -> jnp.ndarray:
        """Forward hidden states through stage ``stage_idx`` (1-indexed)."""
        key = ("fwd", stage_idx, x.shape)
        if key not in self._fwd:
            cfg = self.cfg

            @jax.jit
            def fwd(params, x):
                stage = params["stages"][stage_idx - 1]
                positions = jnp.arange(x.shape[1], dtype=jnp.int32)
                out, _, _ = model_lib._run_stage(stage, x, cfg, positions, "train")
                return out

            self._fwd[key] = fwd
        return self._fwd[key](self.params, x)

    def embed(self, tokens: jnp.ndarray) -> jnp.ndarray:
        key = ("embed", tokens.shape)
        if key not in self._fwd:
            cfg = self.cfg

            @jax.jit
            def emb(params, tokens):
                return model_lib._embed_inputs(params, {"tokens": tokens}, cfg)

            self._fwd[key] = emb
        return self._fwd[key](self.params, tokens)

    def exit_head(self, stage_idx: int, x_last: jnp.ndarray):
        """(confidence, token) of the exit branch after stage ``stage_idx``."""
        key = ("exit", stage_idx, x_last.shape)
        if key not in self._fwd:
            cfg = self.cfg

            @jax.jit
            def head(params, x_last):
                return model_lib.exit_confidence(params, x_last, stage_idx, cfg)

            self._fwd[key] = head
        return self._fwd[key](self.params, x_last)

    def final_head(self, x_last: jnp.ndarray):
        key = ("final", x_last.shape)
        if key not in self._fwd:
            cfg = self.cfg

            @jax.jit
            def head(params, x_last):
                h = layers.apply_norm(cfg.norm, params["final_norm"], x_last)
                logits = model_lib.lm_logits(params, h, cfg)[:, 0]
                conf = jax.nn.softmax(logits, axis=-1).max(axis=-1)
                return conf, jnp.argmax(logits, axis=-1).astype(jnp.int32)

            self._fwd[key] = head
        return self._fwd[key](self.params, x_last)


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ServeStats:
    delays: list[float]
    exit_stage: list[int]
    confidences: list[float]
    tokens: list[int]

    def summary(self) -> dict:
        d = np.asarray(self.delays)
        es = np.asarray(self.exit_stage)
        return {
            "num_completed": int(d.size),
            "mean_delay": float(d.mean()) if d.size else float("nan"),
            "p95_delay": float(np.percentile(d, 95)) if d.size else float("nan"),
            "exit_histogram": {
                int(s): int((es == s).sum()) for s in np.unique(es)
            },
        }


class CollaborativeEngine:
    """End-to-end: Poisson arrivals -> DTO-EE routing -> staged model."""

    def __init__(
        self,
        params: Any,
        cfg: ArchConfig,
        topo: Topology,
        profile: ModelProfile,
        exit_profile: ExitProfile,
        hyper: DtoHyperParams | None = None,
        seed: int = 0,
    ):
        if topo.num_stages != cfg.num_stages:
            raise ValueError("topology stages must match the model's stages")
        self.programs = StagePrograms(params, cfg)
        self.cfg = cfg
        self.topo = topo
        self.profile = profile
        self.exit_profile = exit_profile
        self.hyper = hyper or DtoHyperParams()
        self.rng = np.random.default_rng(seed)
        self.state = dto_ee.init_state(topo, profile, exit_profile)
        self._round_step = dto_ee.make_round_step(topo, profile, self.hyper)
        self.stage_to_branch = {
            s: b for b, s in enumerate(exit_profile.branch_stage[:-1])
        }

    # -- control plane ------------------------------------------------------
    def update_topology(self, new_topo: Topology) -> None:
        """Dynamic environment: capacities / arrival rates changed between
        slots.  The offloading state (p, thresholds) warm-starts; only the
        jitted round program is rebuilt (mu / rates are baked into it)."""
        if new_topo.num_edges != self.topo.num_edges:
            raise ValueError("edge set changed; use runtime.elastic helpers first")
        self.topo = new_topo
        self._round_step = dto_ee.make_round_step(new_topo, self.profile, self.hyper)

    def configuration_phase(self, adapt_thresholds: bool = True) -> None:
        """One time-slot configuration update (Algorithm 3)."""
        res = dto_ee.run_configuration_phase(
            self.topo,
            self.profile,
            self.exit_profile,
            self.hyper,
            state=self.state,
            adapt_thresholds=adapt_thresholds,
            round_step=self._round_step,
        )
        self.state = res.state

    @property
    def p(self) -> np.ndarray:
        return np.asarray(self.state.carry.p, np.float64)

    @property
    def thresholds(self) -> np.ndarray:
        return self.state.thresholds

    # -- data plane ---------------------------------------------------------
    def _route(self, node: int) -> tuple[int, int]:
        lo, hi = self.topo.edge_offsets[node], self.topo.edge_offsets[node + 1]
        probs = self.p[lo:hi]
        s = probs.sum()
        e = (
            lo + int(self.rng.choice(hi - lo, p=probs / s))
            if s > 0
            else int(self.rng.integers(lo, hi))
        )
        return int(self.topo.edge_dst[e]), e

    def serve(
        self,
        prompts: list[np.ndarray],
        duration: float = 5.0,
        arrival_rate: float | None = None,
    ) -> ServeStats:
        """Serve ``prompts`` arriving as a Poisson stream over ``duration``.

        Each request classifies its prompt's next token; exit thresholds are
        the engine's current C.  Batch size 1 per hop keeps the routing
        faithful (each request samples its own path); stage forwards are
        jit-cached per shape so repeated shapes are fast.
        """
        topo, profile = self.topo, self.profile
        H = profile.num_stages
        eds = topo.nodes_at_stage(0)
        rate = arrival_rate or float(topo.phi_ext.sum())
        n = len(prompts)
        arrivals = np.sort(self.rng.uniform(0.0, duration, size=n))

        stats = ServeStats([], [], [], [])
        # event heap: (time, seq, request, node) — arrival of request at node
        heap: list = []
        seq = itertools.count()
        queues = {int(v): 0.0 for v in range(topo.num_nodes)}  # busy-until

        for i, (t, prompt) in enumerate(zip(arrivals, prompts)):
            ed = int(eds[i % len(eds)])
            req = Request(rid=i, tokens=np.asarray(prompt, np.int32), arrival=t)
            nxt, e = self._route(ed)
            t_cm = profile.beta[0] / float(topo.edge_rate[e])
            heapq.heappush(heap, (t + t_cm, next(seq), req, nxt))

        while heap:
            now, _, req, node = heapq.heappop(heap)
            h = int(topo.node_stage[node])
            # ---- real compute: stage forward -------------------------------
            if h == 1:
                x = self.programs.embed(jnp.asarray(req.tokens[None, :]))
            else:
                x = req.hidden
            x = self.programs.run_stage(h, x)
            req.hidden = x

            # ---- service delay: M/D/1 FIFO approximation -------------------
            service = profile.alpha[h - 1] / float(topo.mu[node])
            start = max(now, queues[node])
            done = start + service
            queues[node] = done

            # ---- exit decision with REAL confidence ------------------------
            b = self.stage_to_branch.get(h)
            exits = False
            if b is not None:
                conf, tok = self.programs.exit_head(h, x[:, -1:])
                c, t_ = float(conf[0]), int(tok[0])
                if c >= self.thresholds[b]:
                    exits = True
            if h == H:
                conf, tok = self.programs.final_head(x[:, -1:])
                c, t_ = float(conf[0]), int(tok[0])
                exits = True
            if exits:
                req.exited, req.exit_stage = True, h
                req.confidence, req.output_token = c, t_
                req.t_done = done
                stats.delays.append(req.delay)
                stats.exit_stage.append(h)
                stats.confidences.append(c)
                stats.tokens.append(t_)
                continue

            # ---- offload onward -------------------------------------------
            nxt, e = self._route(node)
            t_cm = profile.beta[h] / float(topo.edge_rate[e])
            heapq.heappush(heap, (done + t_cm, next(seq), req, nxt))

        return stats
