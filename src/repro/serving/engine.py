"""Collaborative serving engine: the paper's system with a real model inside.

A model is partitioned into ``cfg.num_stages`` stages; each stage ``h`` is
served by ``n_h`` replica groups (on a real cluster: mesh slices; here:
logical replicas with Jetson-profiled service rates).  The engine:

  * routes each request hop-by-hop by sampling the DTO-EE offloading
    strategy ``p`` (the control plane runs the genuine RUR/RUS rounds on a
    Topology mirroring the replica layout);
  * runs the REAL stage forward for the data plane — the residual stream is
    handed replica-to-replica, and exit decisions use the model's actual
    branch confidences against the thresholds C (not a table);
  * advances a simulated clock with M/D/1 FIFO service at each replica, so
    measured delays follow the same queueing physics the optimizer models.

Data plane (micro-batched): each replica owns a ``ShapeBucketBatcher``.
Requests landing on a busy replica queue up; whenever the replica frees, it
drains one shape-bucketed batch (up to ``batch_size`` requests of one input
shape), runs a single jitted stage forward for the whole padded batch, and
makes the batched exit decision in one device call — both the early-exit
branches and the final head go through the fused ``exit_confidence`` kernel,
so ``[B, vocab]`` logits never touch HBM on either path.  ``batch_size=1``
reproduces the sequential per-request engine exactly (same clock, same
exits); larger batches trade a little simulated queueing delay for an
order-of-magnitude fewer device dispatches.

This is deliberately a single-process, event-stepped engine: the
distributed *semantics* (who talks to whom, what information each node has)
are faithful; only the transport is in-process.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Any

import numpy as np

import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import dto_ee
from repro.core.simulator import RoutingCdf
from repro.core.thresholds import ExitProfile
from repro.core.types import DtoHyperParams, ModelProfile, Topology
from repro.serving import steps
from repro.serving.batching import (
    Request,
    ShapeBucketBatcher,
    batch_tokens,
    padded_batch_size,
)


# ---------------------------------------------------------------------------
# Stage programs: one jitted program per stage / head, traced per batch shape
# ---------------------------------------------------------------------------


class StagePrograms:
    """Compiled per-stage forwards + fused heads of a partitioned model.

    One jitted callable per stage and per head; jax re-traces per input
    shape, so every (stage, padded-batch shape) bucket compiles once and is
    then served from the executable cache.
    """

    def __init__(self, params: Any, cfg: ArchConfig):
        self.cfg = cfg
        self.params = params
        self._embed = steps.make_embed_step(cfg)
        self._stage = {}
        self._exit = {}
        self._final = steps.make_final_head_step(cfg)

    def embed(self, tokens: jnp.ndarray) -> jnp.ndarray:
        return self._embed(self.params, tokens)

    def run_stage(self, stage_idx: int, x: jnp.ndarray) -> jnp.ndarray:
        """Forward hidden states through stage ``stage_idx`` (1-indexed)."""
        if stage_idx not in self._stage:
            self._stage[stage_idx] = steps.make_stage_forward(self.cfg, stage_idx)
        return self._stage[stage_idx](self.params, x)

    def exit_head(self, stage_idx: int, x_last: jnp.ndarray):
        """(confidence, token) of the exit branch after stage ``stage_idx``."""
        if stage_idx not in self._exit:
            self._exit[stage_idx] = steps.make_exit_head_step(self.cfg, stage_idx)
        return self._exit[stage_idx](self.params, x_last)

    def final_head(self, x_last: jnp.ndarray):
        """(confidence, token) of the final head — fused, no [B, vocab] logits."""
        return self._final(self.params, x_last)


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ServeStats:
    delays: list[float]
    exit_stage: list[int]
    confidences: list[float]
    tokens: list[int]
    rids: list[int] = dataclasses.field(default_factory=list)
    num_batches: int = 0
    num_forward_rows: int = 0  # padded rows pushed through stage forwards

    def summary(self) -> dict:
        d = np.asarray(self.delays)
        es = np.asarray(self.exit_stage)
        return {
            "num_completed": int(d.size),
            "mean_delay": float(d.mean()) if d.size else float("nan"),
            "p95_delay": float(np.percentile(d, 95)) if d.size else float("nan"),
            "exit_histogram": {
                int(s): int((es == s).sum()) for s in np.unique(es)
            },
            "num_batches": self.num_batches,
        }

    def by_rid(self) -> dict[int, tuple[int, int]]:
        """rid -> (exit_stage, token); completion-order independent view."""
        return {
            r: (s, t)
            for r, s, t in zip(self.rids, self.exit_stage, self.tokens)
        }


class CollaborativeEngine:
    """End-to-end: Poisson arrivals -> DTO-EE routing -> staged model."""

    def __init__(
        self,
        params: Any,
        cfg: ArchConfig,
        topo: Topology,
        profile: ModelProfile,
        exit_profile: ExitProfile,
        hyper: DtoHyperParams | None = None,
        seed: int = 0,
    ):
        if topo.num_stages != cfg.num_stages:
            raise ValueError("topology stages must match the model's stages")
        self.programs = StagePrograms(params, cfg)
        self.cfg = cfg
        self.topo = topo
        self.profile = profile
        self.exit_profile = exit_profile
        self.hyper = hyper or DtoHyperParams()
        self.rng = np.random.default_rng(seed)
        self.state = dto_ee.init_state(topo, profile, exit_profile)
        self._round_step = dto_ee.make_round_step(topo, profile, self.hyper)
        self.stage_to_branch = {
            s: b for b, s in enumerate(exit_profile.branch_stage[:-1])
        }

    # -- control plane ------------------------------------------------------
    def update_topology(self, new_topo: Topology) -> None:
        """Dynamic environment: capacities / arrival rates changed between
        slots.  The offloading state (p, thresholds) warm-starts; only the
        jitted round program is rebuilt (mu / rates are baked into it)."""
        if new_topo.num_edges != self.topo.num_edges:
            raise ValueError("edge set changed; use runtime.elastic helpers first")
        self.topo = new_topo
        self._round_step = dto_ee.make_round_step(new_topo, self.profile, self.hyper)

    def configuration_phase(self, adapt_thresholds: bool = True) -> None:
        """One time-slot configuration update (Algorithm 3)."""
        res = dto_ee.run_configuration_phase(
            self.topo,
            self.profile,
            self.exit_profile,
            self.hyper,
            state=self.state,
            adapt_thresholds=adapt_thresholds,
            round_step=self._round_step,
        )
        self.state = res.state

    @property
    def p(self) -> np.ndarray:
        return np.asarray(self.state.carry.p, np.float64)

    @property
    def thresholds(self) -> np.ndarray:
        return self.state.thresholds

    # -- data plane ---------------------------------------------------------
    def _stage_input(self, stage: int, reqs: list[Request], batch_size: int):
        """Assemble the padded [B, S, d] residual stream for one batch.

        Hidden states travel between replicas as host numpy buffers (the
        in-process stand-in for the network hop), so batch assembly is one
        concatenate + one upload instead of per-request device ops.
        """
        if stage == 1:
            return self.programs.embed(batch_tokens(reqs, batch_size))
        hs = [r.hidden for r in reqs]
        B = padded_batch_size(len(reqs), batch_size)
        if B > len(reqs):
            hs.append(np.zeros((B - len(reqs),) + hs[0].shape[1:], hs[0].dtype))
        # host buffer goes straight into the jitted stage (jit device_puts it)
        return np.concatenate(hs, axis=0) if len(hs) > 1 else hs[0]

    def serve(
        self,
        prompts: list[np.ndarray],
        duration: float = 5.0,
        arrival_rate: float | None = None,
        batch_size: int = 1,
    ) -> ServeStats:
        """Serve ``prompts`` arriving as a Poisson stream.

        Arrivals are a genuine Poisson process at ``arrival_rate`` (default:
        the topology's total external rate ``phi_ext.sum()``); ``duration``
        is only the fallback window when no positive rate exists.  Each
        request classifies its prompt's next token; exit thresholds are the
        engine's current C.  ``batch_size`` sets the per-replica micro-batch
        width: each replica drains shape-bucketed padded batches, one jitted
        stage forward and one fused exit decision per batch.  Routing stays
        faithful — every request samples its own path.
        """
        if batch_size < 1:
            raise ValueError("batch_size must be >= 1")
        topo, profile = self.topo, self.profile
        programs = self.programs
        H = profile.num_stages
        eds = topo.nodes_at_stage(0)
        rate = (
            float(arrival_rate)
            if arrival_rate is not None
            else float(topo.phi_ext.sum())
        )
        n = len(prompts)
        if rate > 0 and np.isfinite(rate):
            arrivals = np.cumsum(self.rng.exponential(1.0 / rate, size=n))
        else:
            arrivals = np.sort(self.rng.uniform(0.0, duration, size=n))

        stats = ServeStats([], [], [], [])
        # p is fixed for the duration of the serve call: one precomputed CDF
        # serves every routing sample (shared with the simulator)
        route = RoutingCdf(topo, self.p)
        # event heap: (time, seq, kind, payload)
        #   kind 0: transfer done, request joins ``node``   payload (req, node)
        #   kind 1: batch service done at ``node``          payload (node, reqs,
        #           conf [B] | None, tok [B] | None)
        heap: list = []
        seq = itertools.count()
        pending = {
            int(v): ShapeBucketBatcher(batch_size)
            for v in range(topo.num_nodes)
            if topo.node_stage[v] > 0
        }
        busy_until = {v: 0.0 for v in pending}

        def dispatch(node: int, now: float) -> None:
            """If ``node`` is free, drain one shape bucket and run it."""
            if now < busy_until[node]:
                return
            popped = pending[node].pop_batch()
            if popped is None:
                return
            _, reqs = popped
            h = int(topo.node_stage[node])
            x = programs.run_stage(h, self._stage_input(h, reqs, batch_size))
            b = self.stage_to_branch.get(h)
            conf = tok = None
            if h == H:
                conf, tok = programs.final_head(x)
            elif b is not None:
                conf, tok = programs.exit_head(h, x)
            if h < H:
                x_np = np.asarray(x)
                for i, r in enumerate(reqs):
                    r.hidden = x_np[i : i + 1]
            if conf is not None:
                conf = np.asarray(conf)[: len(reqs)]
                tok = np.asarray(tok)[: len(reqs)]
            stats.num_batches += 1
            stats.num_forward_rows += int(x.shape[0])
            service = len(reqs) * profile.alpha[h - 1] / float(topo.mu[node])
            done = max(now, busy_until[node]) + service
            busy_until[node] = done
            heapq.heappush(heap, (done, next(seq), 1, (node, reqs, conf, tok)))

        def enqueue(req: Request, node: int, now: float) -> None:
            h = int(topo.node_stage[node])
            key = (
                ("tok", int(req.tokens.shape[0]))
                if h == 1
                else ("hid", tuple(req.hidden.shape[1:]))
            )
            req.node = node
            req.stage = h
            pending[node].push(key, req)
            dispatch(node, now)

        def finish(req: Request, node: int, done: float, c: float, t_: int, h: int):
            req.exited, req.exit_stage = True, h
            req.confidence, req.output_token = c, t_
            req.t_done = done
            stats.delays.append(req.delay)
            stats.exit_stage.append(h)
            stats.confidences.append(c)
            stats.tokens.append(t_)
            stats.rids.append(req.rid)

        for i, (t, prompt) in enumerate(zip(arrivals, prompts)):
            ed = int(eds[i % len(eds)])
            req = Request(rid=i, tokens=np.asarray(prompt, np.int32), arrival=t)
            nxt, e = route.sample(self.rng, ed)
            t_cm = profile.beta[0] / float(topo.edge_rate[e])
            heapq.heappush(heap, (t + t_cm, next(seq), 0, (req, nxt)))

        while heap:
            now, _, kind, payload = heapq.heappop(heap)
            if kind == 0:
                req, node = payload
                enqueue(req, node, now)
                continue
            # kind 1: batch done — batched exit decision already on device
            node, reqs, conf, tok = payload
            h = int(topo.node_stage[node])
            b = self.stage_to_branch.get(h)
            for i, req in enumerate(reqs):
                if h == H:
                    finish(req, node, now, float(conf[i]), int(tok[i]), h)
                    continue
                if b is not None and float(conf[i]) >= self.thresholds[b]:
                    finish(req, node, now, float(conf[i]), int(tok[i]), h)
                    continue
                nxt, e = route.sample(self.rng, node)
                t_cm = profile.beta[h] / float(topo.edge_rate[e])
                heapq.heappush(heap, (now + t_cm, next(seq), 0, (req, nxt)))
            dispatch(node, now)

        return stats
