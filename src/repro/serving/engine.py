"""Collaborative serving engine: the paper's system with a real model inside.

A model is partitioned into ``cfg.num_stages`` stages; each stage ``h`` is
served by ``n_h`` replica groups (on a real cluster: mesh slices; here:
logical replicas with Jetson-profiled service rates).  The engine:

  * routes each request hop-by-hop by sampling the DTO-EE offloading
    strategy ``p`` (the control plane runs the genuine RUR/RUS rounds on a
    Topology mirroring the replica layout);
  * runs the REAL stage forward for the data plane — the residual stream is
    handed replica-to-replica, and exit decisions use the model's actual
    branch confidences against the thresholds C (not a table);
  * advances a simulated clock with M/D/1 FIFO service at each replica, so
    measured delays follow the same queueing physics the optimizer models.

Data plane (autoregressive, cache-threaded, continuously batched):

``serve(..., gen_len=N)`` decodes up to N tokens per request.  A request's
first pass is a *prefill* hop chain: stage 1 embeds the prompt, every stage
runs the full-sequence forward, and — in cached mode — writes its stage-local
KV/state caches into a **slot** of that replica's resident cache store.  The
route sampled on this first pass is pinned per stage (``Request.path``), so
each later token returns to the replicas that hold its caches.  Every
subsequent token is a *decode* hop chain: stage 1 embeds one token, each
stage runs a one-token cached step — per-row positions, attention through
``kernels.ops.decode_attention`` (the Pallas flash-decode kernel on TPU) —
so per-token work is O(1) in the prefix length instead of the O(prefix)
re-prefill of the stateless baseline (``decode_mode="stateless"`` keeps that
baseline runnable for A/B benchmarks).  For expanded-attention configs (GQA
/ SSM blocks) the two modes — and the monolithic ``model.prefill`` +
``model.decode_step`` reference — emit bitwise token-identical sequences;
MLA configs decode through the absorbed-latent math, which matches the
monolithic decode reference but, like all absorbed MLA inference, is not
bitwise-equal to re-expanded full-sequence attention.

Continuous batching: replicas own a ring of cache slots.  Whenever a replica
frees at a stage boundary it forms the next batch from whatever waits —
newly-arrived prompts are admitted into free slots alongside in-flight decode
rows, and rows that take an early exit retire immediately, releasing their
slots at every replica on their path without stalling the rest of the batch.
Both the early-exit branches and the final head go through the fused
``exit_confidence`` kernel, so ``[B, vocab]`` logits never touch HBM.

Exit semantics per token: the first branch with conf >= c_h emits the token
and terminates the request (a confident answer); otherwise the final head's
token is appended and decoding continues to ``gen_len``.  ``gen_len=1``
reproduces the paper's single-shot classification plane exactly.

This is deliberately a single-process, event-stepped engine: the
distributed *semantics* (who talks to whom, what information each node has,
which replica holds which cache rows) are faithful; only the transport is
in-process.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from collections import deque
from time import perf_counter
from typing import Any

import numpy as np

import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import dto_ee
from repro.core import topology as topo_lib
from repro.core.simulator import RoutingCdf
from repro.core.thresholds import ExitProfile
from repro.core.types import DtoHyperParams, ModelProfile, Topology
from repro.models import model as model_lib
from repro.runtime import elastic
from repro.serving import steps
from repro.serving.batching import (
    ExitPredictor,
    Request,
    ShapeBucketBatcher,
    SlotRing,
    batch_tokens,
    pack_decode_batch,
    padded_batch_size,
    pow2_floor,
)
from repro.obs.stream import build_stream
from repro.serving.paging import BlockAllocator


def _thinned_arrivals(
    rng: np.random.Generator,
    base_rate: float,
    factor,
    f_max: float,
    n: int,
) -> np.ndarray:
    """Non-homogeneous Poisson arrival times for ``n`` requests by thinning:
    candidates arrive at ``base_rate * f_max`` and are accepted with
    probability ``factor(t) / f_max`` (the scenario's piecewise arrival-rate
    modulation, e.g. a burst window)."""
    lam = base_rate * max(f_max, 1e-12)
    out = np.empty(n, np.float64)
    t = 0.0
    k = 0
    while k < n:
        t += rng.exponential(1.0 / lam)
        if rng.random() * f_max <= factor(t):
            out[k] = t
            k += 1
    return out


# ---------------------------------------------------------------------------
# Stage programs: one jitted program per stage / head, traced per batch shape
# ---------------------------------------------------------------------------


class StagePrograms:
    """Compiled per-stage forwards + fused heads of a partitioned model.

    One jitted callable per stage and per head; jax re-traces per input
    shape, so every (stage, padded-batch shape) bucket compiles once and is
    then served from the executable cache.  The cached-decode plane adds a
    per-stage prefill (cache-building), slot-write (scatter into the
    replica's resident store), and cached one-token decode program.
    """

    def __init__(self, params: Any, cfg: ArchConfig):
        self.cfg = cfg
        self.params = params
        self._embed = steps.make_embed_step(cfg)
        self._stage = {}
        self._exit = {}
        self._final = steps.make_final_head_step(cfg)
        self._prefill = {}
        self._decode = {}
        self._slot_write = {}
        self._paged_decode = {}
        self._paged_write = {}
        self._block_copy = {}

    def embed(self, tokens: jnp.ndarray) -> jnp.ndarray:
        return self._embed(self.params, tokens)

    def run_stage(self, stage_idx: int, x: jnp.ndarray) -> jnp.ndarray:
        """Forward hidden states through stage ``stage_idx`` (1-indexed)."""
        if stage_idx not in self._stage:
            self._stage[stage_idx] = steps.make_stage_forward(self.cfg, stage_idx)
        return self._stage[stage_idx](self.params, x)

    def stage_prefill(self, stage_idx: int, x: jnp.ndarray, max_len: int):
        """(x_out, stage caches [n_periods, B, max_len, ...]) for one stage."""
        key = (stage_idx, max_len)
        if key not in self._prefill:
            self._prefill[key] = steps.make_stage_prefill(self.cfg, stage_idx, max_len)
        return self._prefill[key](self.params, x)

    def stage_decode(self, stage_idx: int, x, slot_caches, slots):
        """One cached token per row against the replica's (donated) store."""
        if stage_idx not in self._decode:
            self._decode[stage_idx] = steps.make_stage_decode(self.cfg, stage_idx)
        return self._decode[stage_idx](self.params, x, slot_caches, slots)

    def slot_write(self, stage_idx: int, slot_caches, new_caches, slots):
        if stage_idx not in self._slot_write:
            self._slot_write[stage_idx] = steps.make_slot_write(self.cfg, stage_idx)
        return self._slot_write[stage_idx](slot_caches, new_caches, slots)

    def init_slot_caches(self, stage_idx: int, num_slots: int, max_len: int):
        return model_lib.init_stage_slot_caches(self.cfg, stage_idx, num_slots, max_len)

    # -- paged layout -------------------------------------------------------
    def init_paged_slot_caches(
        self, stage_idx: int, num_slots: int, num_blocks: int, block_size: int,
        max_len: int,
    ):
        return model_lib.init_stage_paged_caches(
            self.cfg, stage_idx, num_slots, num_blocks, block_size, max_len
        )

    def paged_slot_write(self, stage_idx, pool, state, new_caches, wtab, slots):
        if stage_idx not in self._paged_write:
            self._paged_write[stage_idx] = steps.make_paged_slot_write(
                self.cfg, stage_idx
            )
        return self._paged_write[stage_idx](pool, state, new_caches, wtab, slots)

    def paged_stage_decode(self, stage_idx, x, pool, state, tables, slots, seq_len):
        key = (stage_idx, seq_len)
        if key not in self._paged_decode:
            self._paged_decode[key] = steps.make_paged_stage_decode(
                self.cfg, stage_idx, seq_len
            )
        return self._paged_decode[key](self.params, x, pool, state, tables, slots)

    def block_copy(self, stage_idx, pool, src, dst):
        if stage_idx not in self._block_copy:
            self._block_copy[stage_idx] = steps.make_block_copy(self.cfg, stage_idx)
        return self._block_copy[stage_idx](pool, src, dst)

    def exit_head(self, stage_idx: int, x_last: jnp.ndarray):
        """(confidence, token) of the exit branch after stage ``stage_idx``."""
        if stage_idx not in self._exit:
            self._exit[stage_idx] = steps.make_exit_head_step(self.cfg, stage_idx)
        return self._exit[stage_idx](self.params, x_last)

    def final_head(self, x_last: jnp.ndarray):
        """(confidence, token) of the final head — fused, no [B, vocab] logits."""
        return self._final(self.params, x_last)


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ServeStats:
    delays: list = dataclasses.field(default_factory=list)
    exit_stage: list = dataclasses.field(default_factory=list)
    confidences: list = dataclasses.field(default_factory=list)
    tokens: list = dataclasses.field(default_factory=list)  # last emitted token
    rids: list = dataclasses.field(default_factory=list)
    gen_tokens: list = dataclasses.field(default_factory=list)  # full sequences
    arrivals: list = dataclasses.field(default_factory=list)
    dones: list = dataclasses.field(default_factory=list)
    num_batches: int = 0
    num_forward_rows: int = 0  # padded rows pushed through stage forwards
    num_real_rows: int = 0  # live rows among them (the rest is padding waste)
    # in-flight pressure: live (admitted, unretired) requests over time
    peak_in_flight: int = 0
    # paged layout: prompt blocks served from the prefix map vs allocated,
    # and pool occupancy sampled at every paged batch (per replica)
    prefix_hit_blocks: int = 0
    prefix_total_blocks: int = 0
    block_occupancy: list = dataclasses.field(default_factory=list)
    # online control plane: mid-serve strategy installs, failure re-executions,
    # and the straggler monitor's end-of-serve capacity estimates per ES
    num_reconfigs: int = 0
    reconfig_times: list = dataclasses.field(default_factory=list)
    resubmitted: int = 0
    capacity_estimates: dict = dataclasses.field(default_factory=dict)
    # observability: the SpanTracer / MetricsCollector attached to the serve
    # (None when tracing was off — the zero-cost path)
    trace: Any = None
    metrics: Any = None

    def summary(self) -> dict:
        d = np.asarray(self.delays)
        es = np.asarray(self.exit_stage)
        total_tokens = int(sum(len(g) for g in self.gen_tokens))
        makespan = (
            float(max(self.dones) - min(self.arrivals)) if self.dones else float("nan")
        )
        out = {
            "num_completed": int(d.size),
            "mean_delay": float(d.mean()) if d.size else float("nan"),
            "delay_std": float(d.std()) if d.size else float("nan"),
            "p50_delay": float(np.percentile(d, 50)) if d.size else float("nan"),
            "p95_delay": float(np.percentile(d, 95)) if d.size else float("nan"),
            "p99_delay": float(np.percentile(d, 99)) if d.size else float("nan"),
            "exit_histogram": {
                int(s): int((es == s).sum()) for s in np.unique(es)
            },
            "num_batches": self.num_batches,
            # padded-row waste: fraction of stage-forward rows that were
            # shape-padding rather than live requests
            "num_forward_rows": self.num_forward_rows,
            "num_real_rows": self.num_real_rows,
            "padded_row_frac": (
                1.0 - self.num_real_rows / self.num_forward_rows
                if self.num_forward_rows
                else 0.0
            ),
            "generated_tokens": total_tokens,
            "sim_tokens_per_s": (
                total_tokens / makespan if makespan and makespan > 0 else float("nan")
            ),
            "peak_in_flight": self.peak_in_flight,
            # paged-layout memory stats (zeros/nan under the dense layout)
            "prefix_hit_blocks": self.prefix_hit_blocks,
            "prefix_total_blocks": self.prefix_total_blocks,
            "prefix_hit_rate": (
                self.prefix_hit_blocks / self.prefix_total_blocks
                if self.prefix_total_blocks
                else 0.0
            ),
            "block_occupancy_mean": (
                float(np.mean(self.block_occupancy))
                if self.block_occupancy
                else float("nan")
            ),
            "block_occupancy_peak": (
                float(np.max(self.block_occupancy))
                if self.block_occupancy
                else float("nan")
            ),
            # online control plane
            "num_reconfigs": self.num_reconfigs,
            "resubmitted": self.resubmitted,
            "capacity_estimates": dict(self.capacity_estimates),
        }
        if self.trace is not None:
            from repro.obs.attribution import decompose

            dec = decompose(self.trace, self)
            out["delay_components"] = dec["mean_components_s"]
            out["per_stage_components"] = dec["per_stage"]
        return out

    def report(self) -> dict:
        """Machine-readable serve report: the summary plus, when a tracer
        was attached, the full per-request delay decomposition and, when a
        metrics collector was attached, its registry snapshot."""
        out = {"summary": self.summary()}
        if self.trace is not None:
            from repro.obs.attribution import decompose

            out["decomposition"] = decompose(self.trace, self)
        if self.metrics is not None:
            out["metrics"] = self.metrics.snapshot()
        return out

    def by_rid(self) -> dict[int, tuple[int, int]]:
        """rid -> (exit_stage, token); completion-order independent view."""
        return {
            r: (s, t)
            for r, s, t in zip(self.rids, self.exit_stage, self.tokens)
        }

    def sequences_by_rid(self) -> dict[int, tuple[int, tuple[int, ...]]]:
        """rid -> (exit_stage, full token sequence)."""
        return {
            r: (s, tuple(g))
            for r, s, g in zip(self.rids, self.exit_stage, self.gen_tokens)
        }


class CollaborativeEngine:
    """End-to-end: Poisson arrivals -> DTO-EE routing -> staged model."""

    def __init__(
        self,
        params: Any,
        cfg: ArchConfig,
        topo: Topology,
        profile: ModelProfile,
        exit_profile: ExitProfile,
        hyper: DtoHyperParams | None = None,
        seed: int = 0,
    ):
        if topo.num_stages != cfg.num_stages:
            raise ValueError("topology stages must match the model's stages")
        self.programs = StagePrograms(params, cfg)
        self.cfg = cfg
        self.topo = topo
        self.profile = profile
        self.exit_profile = exit_profile
        self.hyper = hyper or DtoHyperParams()
        self.rng = np.random.default_rng(seed)
        self.state = dto_ee.init_state(topo, profile, exit_profile)
        self._round_step = dto_ee.make_round_step(topo, profile, self.hyper)
        self.stage_to_branch = {
            s: b for b, s in enumerate(exit_profile.branch_stage[:-1])
        }
        # live capacity tracker: every stage batch folds its (GFLOPs, wall)
        # into the EWMA, so a throttled replica's estimate sinks even while
        # the optimizer's view (self.topo) is stale — the measurement half
        # of the closed control loop.  Estimates persist across serves and
        # topology swaps (node ids are stable).
        self.straggler = elastic.StragglerMonitor.from_topology(topo)

    # -- control plane ------------------------------------------------------
    def update_topology(self, new_topo: Topology) -> None:
        """Dynamic environment: capacities / arrival rates changed between
        slots.  The offloading state (p, thresholds) warm-starts; only the
        jitted round program is rebuilt (mu / rates are baked into it)."""
        if new_topo.num_edges != self.topo.num_edges:
            raise ValueError("edge set changed; use runtime.elastic helpers first")
        self.topo = new_topo
        self._round_step = dto_ee.make_round_step(new_topo, self.profile, self.hyper)

    def configuration_phase(self, adapt_thresholds: bool = True) -> None:
        """One time-slot configuration update (Algorithm 3)."""
        res = dto_ee.run_configuration_phase(
            self.topo,
            self.profile,
            self.exit_profile,
            self.hyper,
            state=self.state,
            adapt_thresholds=adapt_thresholds,
            round_step=self._round_step,
        )
        self.state = res.state

    @property
    def p(self) -> np.ndarray:
        return np.asarray(self.state.carry.p, np.float64)

    @property
    def thresholds(self) -> np.ndarray:
        return self.state.thresholds

    # -- data plane ---------------------------------------------------------
    def _stage_input(
        self,
        stage: int,
        reqs: list[Request],
        batch_size: int,
        pad_to: int | None = None,
    ):
        """Assemble the padded [B, S, d] residual stream for one batch.

        Hidden states travel between replicas as host numpy buffers (the
        in-process stand-in for the network hop), so batch assembly is one
        concatenate + one upload instead of per-request device ops.
        ``pad_to`` right-pads the token batch to a fixed sequence length
        (stateless decode passes: a fixed shape keeps every pass's reductions
        length-stable, so re-prefill stays bitwise identical to the
        fixed-arena cached path — and one compiled program serves all steps).
        """
        if stage == 1:
            toks = batch_tokens(reqs, batch_size)
            if pad_to is not None and toks.shape[1] < pad_to:
                toks = np.pad(toks, ((0, 0), (0, pad_to - toks.shape[1])))
            return self.programs.embed(toks)
        hs = [r.hidden for r in reqs]
        B = padded_batch_size(len(reqs), batch_size)
        if B > len(reqs):
            hs.append(np.zeros((B - len(reqs),) + hs[0].shape[1:], hs[0].dtype))
        # host buffer goes straight into the jitted stage (jit device_puts it)
        return np.concatenate(hs, axis=0) if len(hs) > 1 else hs[0]

    def serve(
        self,
        prompts: list[np.ndarray],
        duration: float = 5.0,
        arrival_rate: float | None = None,
        batch_size: int = 1,
        gen_len: int = 1,
        decode_mode: str | None = None,
        num_slots: int | None = None,
        cache_layout: str = "dense",
        block_size: int = 16,
        num_blocks: int | None = None,
        prefix_sharing: bool = True,
        batch_policy: str = "fifo",
        controller=None,
        scenario=None,
        telemetry=None,
        tracer=None,
        metrics=None,
    ) -> ServeStats:
        """Serve ``prompts`` arriving as a Poisson stream.

        Arrivals are a genuine Poisson process at ``arrival_rate`` (default:
        the topology's total external rate ``phi_ext.sum()``); ``duration``
        is only the fallback window when no positive rate exists.  Arrival
        nodes are sampled proportional to each end device's external rate
        ``phi_ext`` — the data plane sees the same traffic mix the optimizer
        models.  Each request autoregressively decodes up to ``gen_len``
        tokens (1 = the paper's single-shot classification); a token taken at
        an early-exit branch terminates its request.  ``batch_size`` sets the
        per-replica micro-batch width.  ``decode_mode``:

          * ``"cached"``    (default for gen_len > 1): stage-local KV caches
            live in per-replica slot rings; decode steps are one-token cached
            programs and new prompts are admitted into running batches at
            stage boundaries (continuous batching).
          * ``"stateless"`` (default for gen_len == 1): every token re-runs
            the full prefix through each stage — the re-prefill baseline.

        Both modes emit token-identical sequences and exit decisions for
        expanded-attention configs (see the module docstring for the MLA
        absorbed-decode caveat).

        ``cache_layout`` picks the slot-store memory layout for cached mode:

          * ``"dense"`` — each slot reserves a worst-case ``max_len`` KV
            arena (the bitwise reference baseline).
          * ``"paged"`` — KV lives in a per-replica pool of ``block_size``-
            token blocks (``num_blocks`` of them; default: the dense
            footprint) addressed through per-request block tables, allocated
            lazily as generations grow.  Identical prompt-prefix blocks are
            shared across requests (``prefix_sharing``) with copy-on-write,
            so a replica holds several times more in-flight requests in the
            same KV bytes.  Emitted tokens and exits are bitwise identical
            to the dense layout; admission additionally waits for pool
            blocks, and a serve whose pool is too small for its working set
            raises instead of deadlocking silently.

        Online control plane (``repro.control``):

          * ``telemetry`` — a streaming sink (``Telemetry`` or anything with
            its hook methods) receiving per-arrival / per-batch /
            per-transfer / per-exit observations as the simulated clock
            advances.

        Observability (``repro.obs``): ``telemetry``, ``tracer`` and
        ``metrics`` all subscribe to ONE instrumentation stream — a single
        set of emission points on the engine's hot paths
        (:mod:`repro.obs.stream`).  ``tracer`` (a ``SpanTracer``) builds one
        span tree per request tiling ``[arrival, retirement]`` exactly —
        admission wait, per-hop transfer, queue wait, batch-formation wait,
        stage compute — plus instants and counter samples, and accumulates
        REAL wall-clock per stage program for the roofline join.
        ``metrics`` (a ``MetricsCollector``) feeds a metrics registry
        (p50/p95/p99 delay, batch occupancy, pool occupancy, realized exit
        pairs).  With none attached the stream is ``None`` and every
        emission site is skipped — the disabled path is bitwise identical
        and overhead-free.  Attached observers land on ``stats.trace`` /
        ``stats.metrics`` for ``ServeStats.report()`` and the exporters.
          * ``controller`` — a ``ReconfigController``; every
            ``controller.interval`` sim-seconds it plans a reconfiguration
            from the telemetry's measured topology and, after the plan's
            decision time has elapsed (routing stays on the stale strategy
            meanwhile, as the paper charges slow deciders), atomically
            installs the new ``p``/thresholds into the engine.
          * ``scenario`` — a ``Scenario`` of timed environment
            perturbations (bursts, slowdowns, link degradation, node
            failure).  Physics then run on a private copy of the serve-time
            topology: ``self.topo`` stays the optimizer's view and only
            learns of the drift through telemetry + reconfiguration.
            Failure events re-execute every task resident on the dead
            replica from its source ED and require the stateless
            single-shot plane (gen_len=1); cache migration is a follow-on.
          * ``batch_policy="threshold"`` — threshold-aware batch packing:
            decode batches are filled with rows sharing the head row's
            predicted retirement class (confidence history vs the *current*
            thresholds) so batches retire together, and takes are trimmed
            to exact padded shapes — recovering ``padded_row_frac`` waste
            with token-identical outputs.
        """
        if batch_size < 1:
            raise ValueError("batch_size must be >= 1")
        if gen_len < 1:
            raise ValueError("gen_len must be >= 1")
        if cache_layout not in ("dense", "paged"):
            raise ValueError("cache_layout must be 'dense' or 'paged'")
        paged = cache_layout == "paged"
        if decode_mode is None:
            decode_mode = "cached" if (gen_len > 1 or paged) else "stateless"
        if decode_mode not in ("cached", "stateless"):
            raise ValueError("decode_mode must be 'cached' or 'stateless'")
        if paged and decode_mode != "cached":
            raise ValueError("cache_layout='paged' requires decode_mode='cached'")
        if paged and block_size < 1:
            raise ValueError("block_size must be >= 1")
        cached = decode_mode == "cached"
        if gen_len > 1 and self.cfg.frontend != "tokens":
            raise ValueError("autoregressive decode needs a token frontend")
        if any(int(p.shape[0]) < 1 for p in prompts):
            raise ValueError("prompts must be non-empty")
        if batch_policy not in ("fifo", "threshold"):
            raise ValueError("batch_policy must be 'fifo' or 'threshold'")
        if controller is not None and telemetry is None:
            telemetry = controller.telemetry
        if scenario is not None and any(
            ev.kind == "fail" for ev in scenario.events
        ) and (cached or gen_len > 1):
            raise ValueError(
                "failure scenarios re-execute tasks from their source ED and "
                "need the stateless single-shot plane (gen_len=1, "
                "decode_mode='stateless'); cache migration is a follow-on"
            )
        profile = self.profile
        if scenario is not None:
            # physics run on a PRIVATE copy of the serve-time topology: the
            # scenario mutates physical truth, while self.topo remains the
            # optimizer's view and only learns of the drift through
            # telemetry + reconfiguration (the closed loop under test)
            topo = dataclasses.replace(
                self.topo,
                mu=self.topo.mu.copy(),
                phi_ext=self.topo.phi_ext.copy(),
                edge_rate=self.topo.edge_rate.copy(),
            )
        else:
            topo = self.topo
        programs = self.programs
        H = profile.num_stages
        eds = topo.nodes_at_stage(0)
        rate = (
            float(arrival_rate)
            if arrival_rate is not None
            else float(topo.phi_ext.sum())
        )
        n = len(prompts)
        if rate > 0 and np.isfinite(rate):
            if scenario is not None and scenario.modulates_arrivals:
                arrivals = _thinned_arrivals(
                    self.rng,
                    rate,
                    scenario.arrival_factor,
                    scenario.max_arrival_factor,
                    n,
                )
            else:
                arrivals = np.cumsum(self.rng.exponential(1.0 / rate, size=n))
        else:
            arrivals = np.sort(self.rng.uniform(0.0, duration, size=n))
        # arrival nodes follow the optimizer's traffic model: each request
        # lands on an ED with probability proportional to its phi_ext
        ed_w = topo.phi_ext[eds]
        if n and ed_w.sum() > 0:
            if scenario is not None and scenario.modulates_eds:
                # scenario skews WHICH devices produce during its windows
                ed_idx = np.empty(n, np.int64)
                for i, t in enumerate(arrivals):
                    w = scenario.ed_weights(float(t), eds, ed_w)
                    ed_idx[i] = self.rng.choice(len(eds), p=w / w.sum())
            else:
                ed_idx = self.rng.choice(len(eds), size=n, p=ed_w / ed_w.sum())
        else:
            ed_idx = np.arange(n) % max(len(eds), 1)
        packer = None
        if batch_policy == "threshold":
            # reads self.thresholds lazily, so mid-serve reconfigurations
            # re-aim the exit predictions immediately
            packer = ExitPredictor(lambda: self.thresholds, gen_len)
        # one capacity EWMA, not two: the telemetry adopts the engine's
        # monitor so the capacity_estimates reported in ServeStats are
        # exactly the numbers the controller planned from
        shared_monitor = telemetry is not None and hasattr(
            telemetry, "attach_monitor"
        )
        if shared_monitor:
            telemetry.attach_monitor(self.straggler)
        # every observer subscribes to one instrumentation stream; None when
        # nothing is attached, so the disabled path skips every emission
        stream = build_stream(telemetry, tracer, metrics)
        wants_wall = stream is not None and stream.wants_wall

        stats = ServeStats()
        stats.trace = tracer
        stats.metrics = metrics
        # one precomputed CDF serves every routing sample (shared with the
        # simulator); the controller's installs and node failures rebuild it
        route = RoutingCdf(topo, self.p)
        # event heap: (time, seq, kind, payload)
        #   kind 0: transfer done, request joins ``node``   payload (req, node)
        #   kind 1: batch service done at ``node``          payload (node, reqs,
        #           conf [B] | None, tok [B] | None, is_decode_pass)
        #   kind 2: control plane                           payload ("scenario",
        #           event idx) | ("reconfig",) | ("install", plan)
        #   kind 3: deferred ED arrival (scenario runs only; the first hop's
        #           transfer time must see the environment AT arrival time)
        #           payload: req
        heap: list = []
        dead_nodes: set[int] = set()
        seq = itertools.count()
        wait_seq = itertools.count()  # FIFO order shared across queue kinds
        es_nodes = [int(v) for v in range(topo.num_nodes) if topo.node_stage[v] > 0]
        pending = {v: ShapeBucketBatcher(batch_size, seq=wait_seq) for v in es_nodes}
        busy_until = {v: 0.0 for v in es_nodes}
        decode_q: dict[int, deque] = {v: deque() for v in es_nodes}
        rings: dict[int, SlotRing] = {}
        slot_store: dict[int, Any] = {}
        pool_store: dict[int, Any] = {}
        state_store: dict[int, Any] = {}
        allocators: dict[int, BlockAllocator] = {}
        trash = -1
        trash_block = -1
        n_logical = 0
        max_len = max((int(p.shape[0]) for p in prompts), default=1) + gen_len
        if cached:
            n_slots = num_slots if num_slots is not None else max(2 * batch_size, 4)
            trash = n_slots  # extra store row absorbing padded-row writes
            if paged:
                n_logical = -(-max_len // block_size)
                # default pool: the dense layout's footprint, block-granular
                n_blocks = (
                    num_blocks if num_blocks is not None else n_slots * n_logical
                )
                trash_block = n_blocks  # extra pool row absorbing trash writes
                for v in es_nodes:
                    rings[v] = SlotRing(n_slots)
                    allocators[v] = BlockAllocator(
                        n_blocks, block_size, prefix_sharing=prefix_sharing
                    )
                    pool_store[v], state_store[v] = programs.init_paged_slot_caches(
                        int(topo.node_stage[v]),
                        n_slots + 1,
                        n_blocks + 1,
                        block_size,
                        max_len,
                    )
            else:
                for v in es_nodes:
                    rings[v] = SlotRing(n_slots)
                    slot_store[v] = programs.init_slot_caches(
                        int(topo.node_stage[v]), n_slots + 1, max_len
                    )
        live_reqs = 0  # admitted somewhere, not yet retired
        # paged admission reserves each row's worst-case REMAINING blocks
        # (it can still write up to prompt + gen_len - 1 positions), so a
        # live row's decode appends can never starve — deadlock-freedom
        # without preemption.  The occupancy win over dense comes from
        # reserving each request's OWN worst case instead of max_len, plus
        # prefix sharing keeping actual allocation below the reservation.
        reserved = {v: 0 for v in es_nodes} if paged else {}

        def total_blocks(prompt_len: int) -> int:
            return -(-(prompt_len + gen_len - 1) // block_size)

        def run_prefill(node: int, reqs: list[Request], now: float) -> None:
            nonlocal live_reqs
            wall_t0 = perf_counter() if wants_wall else 0.0
            h = int(topo.node_stage[node])
            # stateless decode passes run at a FIXED padded length: causal
            # masking makes the pad rows inert, the valid rows stay bitwise
            # identical to the fixed-size cached arena, and one compiled
            # program serves every step of the generation
            stateless_decode = not cached and reqs[0].phase == "decode"
            pad_to = max_len if stateless_decode else None
            x_in = self._stage_input(h, reqs, batch_size, pad_to=pad_to)
            if cached:
                x, caches = programs.stage_prefill(h, x_in, max_len)
                slots = np.full((int(x.shape[0]),), trash, np.int32)
                for i, r in enumerate(reqs):
                    s = rings[node].alloc()
                    assert s is not None, "dispatch admitted beyond ring capacity"
                    if not r.slots:  # first residency anywhere: now in flight
                        live_reqs += 1
                        stats.peak_in_flight = max(stats.peak_in_flight, live_reqs)
                    r.slots[node] = s
                    slots[i] = s
                if paged:
                    alloc = allocators[node]
                    wtab = np.full(
                        (int(x.shape[0]), n_logical), trash_block, np.int32
                    )
                    batch_hits = batch_total = 0
                    for i, r in enumerate(reqs):
                        res = alloc.alloc(r.tokens.tolist())
                        assert res is not None, (
                            "dispatch admitted beyond block-pool capacity"
                        )
                        r.block_seq[node] = res.handle
                        reserved[node] += total_blocks(r.prompt_len) - len(res.table)
                        for j, (blk, shared) in enumerate(
                            zip(res.table, res.shared)
                        ):
                            # shared blocks already hold this prefix — never
                            # rewrite them (other rows read them); redirect
                            # the write to the trash block
                            wtab[i, j] = trash_block if shared else blk
                        batch_hits += sum(res.shared)
                        batch_total += len(res.table)
                    stats.prefix_hit_blocks += batch_hits
                    stats.prefix_total_blocks += batch_total
                    pool_store[node], state_store[node] = programs.paged_slot_write(
                        h, pool_store[node], state_store[node], caches, wtab, slots
                    )
                    stats.block_occupancy.append(alloc.used_fraction)
                    if stream is not None:
                        stream.on_pool(
                            now, node, alloc.used_fraction,
                            batch_hits, batch_total,
                        )
                else:
                    slot_store[node] = programs.slot_write(
                        h, slot_store[node], caches, slots
                    )
            else:
                x = programs.run_stage(h, x_in)
            last = (
                int(reqs[0].all_tokens().shape[0]) if stateless_decode else None
            )
            finish_pass(
                node, reqs, x, now, h, is_decode_pass=False, last_valid=last,
                wall_t0=wall_t0,
            )

        def run_decode(node: int, reqs: list[Request], now: float) -> None:
            wall_t0 = perf_counter() if wants_wall else 0.0
            h = int(topo.node_stage[node])
            B = len(reqs)
            Bp = padded_batch_size(B, batch_size)
            slots = np.full((Bp,), trash, np.int32)
            for i, r in enumerate(reqs):
                slots[i] = r.slots[node]
            if h == 1:
                toks = np.zeros((Bp, 1), np.int32)
                for i, r in enumerate(reqs):
                    toks[i, 0] = r.generated[-1]
                x_in = programs.embed(toks)
            else:
                hs = [r.hidden for r in reqs]
                if Bp > B:
                    hs.append(np.zeros((Bp - B,) + hs[0].shape[1:], hs[0].dtype))
                x_in = np.concatenate(hs, axis=0) if len(hs) > 1 else hs[0]
            if paged:
                alloc = allocators[node]
                rtab = np.full((Bp, n_logical), trash_block, np.int32)
                for i, r in enumerate(reqs):
                    # grow the row by one position (dispatch budgeted this);
                    # crossing a block boundary takes a fresh pool block, and
                    # a fork-shared target block is copied before the write
                    res = alloc.append(r.block_seq[node])
                    assert res is not None, (
                        "dispatch scheduled a decode row beyond pool capacity"
                    )
                    if res.new_block:
                        reserved[node] -= 1  # consumed part of the reservation
                    # the engine never forks and shares only full blocks
                    # strictly inside the prompt, while appends target
                    # pos >= prompt_len — so copy-on-write cannot trigger
                    # here (a reachable COW would also need charging against
                    # ``reserved``; see programs.block_copy for the device
                    # half when preemption/fork lands)
                    assert res.cow is None, "append hit a shared block"
                    tab = alloc.table(r.block_seq[node])
                    rtab[i, : len(tab)] = tab
                x, pool_store[node], state_store[node] = programs.paged_stage_decode(
                    h, x_in, pool_store[node], state_store[node], rtab, slots,
                    max_len,
                )
                stats.block_occupancy.append(alloc.used_fraction)
                if stream is not None:
                    stream.on_pool(now, node, alloc.used_fraction)
            else:
                x, slot_store[node] = programs.stage_decode(
                    h, x_in, slot_store[node], slots
                )
            finish_pass(node, reqs, x, now, h, is_decode_pass=True, wall_t0=wall_t0)

        def finish_pass(
            node: int,
            reqs: list[Request],
            x,
            now: float,
            h: int,
            is_decode_pass: bool,
            last_valid: int | None = None,
            wall_t0: float = 0.0,
        ) -> None:
            """Shared tail of a stage batch: heads, handoff buffers, clock.

            ``last_valid`` points the heads at the last REAL position of a
            right-padded stateless decode pass (the heads otherwise read the
            final position).
            """
            b = self.stage_to_branch.get(h)
            x_heads = x if last_valid is None else x[:, last_valid - 1 : last_valid]
            conf = tok = None
            if h == H:
                conf, tok = programs.final_head(x_heads)
            elif b is not None:
                conf, tok = programs.exit_head(h, x_heads)
            if h < H:
                x_np = np.asarray(x)
                for i, r in enumerate(reqs):
                    r.hidden = x_np[i : i + 1]
            if conf is not None:
                conf = np.asarray(conf)[: len(reqs)]
                tok = np.asarray(tok)[: len(reqs)]
            stats.num_batches += 1
            stats.num_forward_rows += int(x.shape[0])
            stats.num_real_rows += len(reqs)
            if is_decode_pass:
                # clock model: alpha[h] is the profiled cost of one TASK
                # (= its prompt) at stage h, so one cached token is charged
                # that task's per-token share, alpha / prompt_len — O(1) in
                # the prefix versus the full alpha a stateless re-prefill
                # pass pays
                gflops = profile.alpha[h - 1] * sum(
                    1.0 / r.prompt_len for r in reqs
                )
            else:
                gflops = len(reqs) * profile.alpha[h - 1]
            service = gflops / float(topo.mu[node])
            start = max(now, busy_until[node])
            done = start + service
            busy_until[node] = done
            # every batch is a capacity measurement: the EWMA follows the
            # replica's TRUE (possibly scenario-perturbed) rate, feeding the
            # controller's effective topology (telemetry.on_batch folds the
            # observation into the shared monitor; observe directly only
            # when no telemetry shares it)
            if not shared_monitor:
                self.straggler.observe(node, gflops, service)
            if stream is not None:
                # by this point the heads/handoff buffers were pulled to
                # host, so the real stage programs have completed — the
                # perf_counter delta is honest device+dispatch wall time
                stream.on_batch(
                    done,
                    node,
                    gflops,
                    service,
                    len(pending[node]) + len(decode_q[node]),
                    stage=h,
                    rids=tuple(r.rid for r in reqs),
                    t_dispatch=now,
                    t_start=start,
                    n_rows=int(x.shape[0]),
                    n_tokens=int(x.shape[0]) * int(x.shape[1]),
                    is_decode=is_decode_pass,
                    wall_clock_s=(perf_counter() - wall_t0) if wants_wall else 0.0,
                )
            heapq.heappush(
                heap, (done, next(seq), 1, (node, reqs, conf, tok, is_decode_pass))
            )

        def dispatch(node: int, now: float) -> None:
            """If ``node`` is free, form one batch and run it.

            FIFO across work kinds by arrival order, except that prompts
            blocked on slot space never stall waiting decode rows — that is
            the continuous-batching invariant.
            """
            if now < busy_until[node]:
                return
            ph = pending[node].head_seq()
            prompt_blocks = 0
            if ph is not None and cached and rings[node].available == 0:
                ph = None  # admission blocked until a retirement frees a slot
            if ph is not None and paged:
                # admission also waits for pool blocks: each admitted row
                # reserves its sharing-blind worst-case TOTAL (prompt +
                # generation), so in-flight decode appends can never starve
                _, head = pending[node].peek()
                prompt_blocks = total_blocks(head.prompt_len)
                if allocators[node].free_blocks - reserved[node] < prompt_blocks:
                    ph = None
            dq = decode_q[node]
            if paged and dq:
                # take FIFO decode rows whose next-position block needs fit
                # the pool right now; rows that can't extend wait without
                # masking runnable work behind them
                budget = allocators[node].free_blocks
                take: list = []
                rest: list = []
                for item in dq:
                    cost = allocators[node].append_cost(item[1].block_seq[node])
                    if len(take) < batch_size and cost <= budget:
                        take.append(item)
                        budget -= cost
                    else:
                        rest.append(item)
                if packer is not None and take:
                    # threshold-aware packing on top of the budget filter:
                    # group the eligible rows by predicted retirement class
                    # and trim to an exact padded shape; bumped rows rejoin
                    # the queue in FIFO (seq) order
                    take, back = pack_decode_batch(take, batch_size, packer)
                    rest = sorted(back + rest)
                dh = take[0][0] if take else None
            else:
                take = rest = []
                dh = dq[0][0] if dq else None
            if ph is None and dh is None:
                return
            if dh is not None and (ph is None or dh < ph):
                if paged:
                    dq.clear()
                    dq.extend(rest)
                    reqs = [r for _, r in take]
                elif packer is not None:
                    take, rest = pack_decode_batch(list(dq), batch_size, packer)
                    dq.clear()
                    dq.extend(rest)
                    reqs = [r for _, r in take]
                else:
                    reqs = [dq.popleft()[1] for _ in range(min(batch_size, len(dq)))]
                run_decode(node, reqs, now)
                return
            max_take = rings[node].available if cached else None
            if paged:
                headroom = allocators[node].free_blocks - reserved[node]
                max_take = min(max_take, headroom // max(prompt_blocks, 1))
            if packer is not None:
                # trim the prefill take so the padded batch holds no dead
                # rows (padded_batch_size pads to the next power of two)
                head_len = pending[node].head_len()
                cap = min(head_len, batch_size)
                if max_take is not None:
                    cap = min(cap, max_take)
                if cap >= 1:
                    trim = pow2_floor(cap)
                    max_take = trim if max_take is None else min(max_take, trim)
            popped = pending[node].pop_batch(max_take)
            if popped is None:
                return
            _, reqs = popped
            run_prefill(node, reqs, now)

        def enqueue(req: Request, node: int, now: float) -> None:
            h = int(topo.node_stage[node])
            req.node = node
            req.stage = h
            if stream is not None:
                stream.on_enqueue(now, req.rid, node)
            if req.phase == "decode" and cached:
                decode_q[node].append((next(wait_seq), req))
            else:
                if req.phase == "decode":
                    # stateless decode pass: padded shapes are uniform, so
                    # bucket by the VALID prefix length (heads slice there)
                    key = ("dec", int(req.all_tokens().shape[0]))
                elif h == 1:
                    key = ("tok", int(req.all_tokens().shape[0]))
                else:
                    key = ("hid", tuple(req.hidden.shape[1:]))
                pending[node].push(key, req)
            dispatch(node, now)

        def finish(req: Request, done: float, c: float, h: int) -> None:
            nonlocal live_reqs
            req.exited, req.exit_stage = True, h
            req.confidence, req.output_token = c, req.generated[-1]
            req.t_done = done
            stats.delays.append(req.delay)
            stats.exit_stage.append(h)
            stats.confidences.append(c)
            stats.tokens.append(req.generated[-1])
            stats.rids.append(req.rid)
            stats.gen_tokens.append(tuple(req.generated))
            stats.arrivals.append(req.arrival)
            stats.dones.append(done)
            if stream is not None:
                stream.on_exit(done, req.rid, h, c)
            if cached and req.slots:
                live_reqs -= 1
                freed = list(req.slots.items())
                req.slots = {}
                for v, s in freed:
                    rings[v].free(s)
                if paged:
                    for v, handle in req.block_seq.items():
                        # release the unused tail of the worst-case reservation
                        reserved[v] -= total_blocks(req.prompt_len) - len(
                            allocators[v].table(handle)
                        )
                        allocators[v].free(handle)
                    req.block_seq = {}
                for v, _ in freed:
                    # a freed slot/block can unblock admission-waiting
                    # prompts and pool-starved decode rows
                    if pending[v].head_seq() is not None or (
                        paged and decode_q[v]
                    ):
                        dispatch(v, done)

        def submit(req: Request, t: float) -> None:
            """First hop: sample a stage-1 replica and ship the raw task."""
            nxt, e = route.sample(self.rng, req.ed)
            req.path[1] = (nxt, int(e))
            t_cm = profile.beta[0] / float(topo.edge_rate[e])
            if stream is not None:
                stream.on_submit(t, req.rid, req.ed, req.arrival)
                stream.on_transfer(
                    t, t + t_cm, t_cm, req.ed, nxt, req.rid, profile.beta[0]
                )
            heapq.heappush(heap, (t + t_cm, next(seq), 0, (req, nxt)))

        def resubmit(req: Request, now: float) -> None:
            """Fail-stop re-execution: a task resident on (or in flight to) a
            failed replica restarts from scratch at its source ED."""
            stats.resubmitted += 1
            req.attempts += 1
            req.phase = "prefill"
            req.hidden = None
            req.generated.clear()
            req.path.clear()
            req.last_conf.clear()
            if stream is not None:
                stream.on_resubmit(now, req.rid)
            submit(req, now)

        for i, (t, prompt) in enumerate(zip(arrivals, prompts)):
            ed = int(eds[ed_idx[i]])
            req = Request(
                rid=i, tokens=np.asarray(prompt, np.int32), arrival=t, ed=ed
            )
            if scenario is not None:
                # defer the first hop to arrival time so it sees the
                # environment (link rates, routing strategy) AS OF ``t``
                heapq.heappush(heap, (float(t), next(seq), 3, req))
            else:
                submit(req, t)

        if scenario is not None:
            for i, ev in enumerate(scenario.events):
                heapq.heappush(heap, (float(ev.time), next(seq), 2, ("scenario", i)))
        if controller is not None:
            heapq.heappush(
                heap,
                (float(controller.interval), next(seq), 2, ("reconfig",)),
            )

        while heap:
            if len(stats.delays) == n:
                break  # all requests measured; only control events remain
            now, _, kind, payload = heapq.heappop(heap)
            if kind == 3:  # deferred ED arrival
                submit(payload, now)
                continue
            if kind == 2:  # control plane
                tag = payload[0]
                if tag == "scenario":
                    ev = scenario.events[payload[1]]
                    if ev.kind == "fail":
                        # (cached failure was rejected up front: no request
                        # can hold cache residency at the dead replica)
                        dead = int(ev.node)
                        # detection is instant: view AND environment drop the
                        # dead replica's edges in lockstep (same predicate, so
                        # structures stay aligned), the surviving strategy is
                        # renormalized, and the optimizer warm-starts from it
                        new_view, p_new = elastic.handle_failure(
                            self.topo, self.p, dead
                        )
                        env_new = (
                            new_view
                            if topo is self.topo
                            else topo_lib.with_node_failure(topo, dead)
                        )
                        self.topo = new_view
                        self.state = dataclasses.replace(
                            self.state,
                            carry=self.state.carry._replace(
                                p=jnp.asarray(p_new, jnp.float32)
                            ),
                        )
                        self._round_step = dto_ee.make_round_step(
                            new_view, profile, self.hyper
                        )
                        topo = env_new
                        route = RoutingCdf(topo, self.p)
                        dead_nodes.add(dead)
                        self.straggler.mu_hat[dead] = 1e-9
                        if stream is not None:
                            stream.on_failure(now, dead)
                        # tasks queued at the dead replica re-execute from
                        # their source EDs (in-service and in-flight ones are
                        # caught at their event pops via ``dead_nodes``)
                        while True:
                            popped = pending[dead].pop_batch()
                            if popped is None:
                                break
                            for r in popped[1]:
                                resubmit(r, now)
                    else:
                        scenario.apply_env(ev, topo)
                elif tag == "reconfig":
                    plan = controller.plan(self, now)
                    if plan is not None:
                        # routing stays on the stale strategy until the
                        # decision time has elapsed — slow reconfigurations
                        # pay for their latency exactly as in the paper
                        heapq.heappush(
                            heap,
                            (
                                now + plan.decision_time,
                                next(seq),
                                2,
                                ("install", plan),
                            ),
                        )
                    # reschedule only while data-plane events remain: a
                    # starved serve must drain to the loud stall check below
                    # instead of ticking forever
                    if any(ev[2] != 2 for ev in heap):
                        heapq.heappush(
                            heap,
                            (now + controller.interval, next(seq), 2, ("reconfig",)),
                        )
                else:  # install
                    if controller.install(self, payload[1]):
                        route = RoutingCdf(topo, self.p)
                        stats.num_reconfigs += 1
                        stats.reconfig_times.append(now)
                continue
            if kind == 0:
                req, node = payload
                if node in dead_nodes:
                    resubmit(req, now)
                    continue
                if stream is not None and req.stage == 0:
                    stream.on_arrival(req.arrival, req.ed, req.rid)
                enqueue(req, node, now)
                continue
            # kind 1: batch done — batched exit decision already on device
            node, reqs, conf, tok, is_decode_pass = payload
            if node in dead_nodes:
                # the replica died mid-service: its output is lost, the
                # whole batch re-executes from the source EDs
                for req in reqs:
                    resubmit(req, now)
                continue
            h = int(topo.node_stage[node])
            b = self.stage_to_branch.get(h)
            for i, req in enumerate(reqs):
                if h == H:
                    req.generated.append(int(tok[i]))
                    if len(req.generated) >= gen_len:
                        finish(req, now, float(conf[i]), h)
                        continue
                    # loop back for the next token: one-token payload to the
                    # request's pinned stage-1 replica
                    req.phase = "decode"
                    node1, e1 = req.path[1]
                    t_cm = (
                        profile.beta[0]
                        / float(topo.edge_rate[e1])
                        / req.prompt_len
                    )
                    if stream is not None:
                        # telemetry never saw this hop pre-refactor (the
                        # modeled per-token payload is not a fresh link
                        # observation), so it is a distinct event the
                        # tracer consumes and the estimators ignore
                        stream.on_loopback(
                            now, now + t_cm, node, node1, req.rid,
                            profile.beta[0] / req.prompt_len,
                        )
                    heapq.heappush(heap, (now + t_cm, next(seq), 0, (req, node1)))
                    continue
                if b is not None:
                    # confidence history feeds the threshold-aware packer's
                    # exit predictions for this row's NEXT token
                    req.last_conf[b] = float(conf[i])
                    if float(conf[i]) >= self.thresholds[b]:
                        # confident early exit: emit and retire
                        req.generated.append(int(tok[i]))
                        finish(req, now, float(conf[i]), h)
                        continue
                nh = h + 1
                if nh in req.path:
                    nxt, e = req.path[nh]
                else:
                    nxt, e = route.sample(self.rng, node)
                    req.path[nh] = (nxt, int(e))
                t_cm = profile.beta[h] / float(topo.edge_rate[e])
                if is_decode_pass:
                    t_cm /= req.prompt_len
                if stream is not None:
                    stream.on_transfer(
                        now,
                        now + t_cm,
                        t_cm,
                        node,
                        nxt,
                        req.rid,
                        profile.beta[h] / (req.prompt_len if is_decode_pass else 1),
                    )
                heapq.heappush(heap, (now + t_cm, next(seq), 0, (req, nxt)))
            dispatch(node, now)

        stats.capacity_estimates = {
            int(v): float(self.straggler.mu_hat[v]) for v in es_nodes
        }
        if len(stats.delays) != n:
            # a stall is resource starvation no future event can clear —
            # fail loudly rather than silently drop requests
            hint = (
                "the KV block pool cannot cover the in-flight working set — "
                "raise num_blocks, shrink num_slots, or use "
                "cache_layout='dense'"
                if paged
                else "requests were left queued with no runnable work"
            )
            raise RuntimeError(
                f"serve stalled with {n - len(stats.delays)} of {n} requests "
                f"unfinished; {hint}"
            )
        return stats
