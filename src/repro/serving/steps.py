"""jit-able serve steps: staged forwards, fused exit heads, prefill / decode.

These are the functions the serving engine and the multi-pod dry-run lower
for the inference shapes: static shapes, cache-in/cache-out, thresholds as a
traced vector so one compiled program serves every threshold setting DTO-EE
picks.  The per-stage builders below are what the micro-batched data plane
runs once per padded batch (jax re-traces per shape, so each builder yields
one compiled program per batch bucket).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import model as model_lib


# ---------------------------------------------------------------------------
# Per-stage programs for the micro-batched engine
# ---------------------------------------------------------------------------


def make_embed_step(cfg: ArchConfig):
    """tokens [B, S] -> embedded residual stream [B, S, d]."""

    @jax.jit
    def embed_step(params: Any, tokens: jnp.ndarray) -> jnp.ndarray:
        return model_lib._embed_inputs(params, {"tokens": tokens}, cfg)

    return embed_step


def make_stage_forward(cfg: ArchConfig, stage_idx: int):
    """Residual stream through stage ``stage_idx`` (1-indexed), any batch."""

    @jax.jit
    def stage_forward(params: Any, x: jnp.ndarray) -> jnp.ndarray:
        stage = params["stages"][stage_idx - 1]
        positions = jnp.arange(x.shape[1], dtype=jnp.int32)
        out, _, _ = model_lib._run_stage(stage, x, cfg, positions, "train")
        return out

    return stage_forward


def make_exit_head_step(cfg: ArchConfig, stage_idx: int):
    """Fused (confidence, token) of exit branch b_h on x [B, S, d].

    The last-token slice happens inside the jitted program so the engine
    pays one device call per batch, not one per slice.
    """

    @jax.jit
    def exit_head_step(params: Any, x: jnp.ndarray):
        return model_lib.exit_confidence(params, x[:, -1:], stage_idx, cfg)

    return exit_head_step


def make_final_head_step(cfg: ArchConfig):
    """Fused (confidence, token) of the final head on x [B, S, d]."""

    @jax.jit
    def final_head_step(params: Any, x: jnp.ndarray):
        return model_lib.final_confidence(params, x[:, -1:], cfg)

    return final_head_step


def select_exit(
    next_token: jnp.ndarray,  # [B] final-head tokens
    exit_conf: jnp.ndarray,  # [B, n_exits]
    exit_tok: jnp.ndarray,  # [B, n_exits]
    thresholds: jnp.ndarray,  # [n_exits]
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Paper's exit rule: first branch with conf >= c_h wins, else final head.

    Returns (token [B], exit_stage_index [B] — n_exits means the final head).
    """
    B, n_exits = exit_conf.shape
    if n_exits == 0:
        return next_token, jnp.full((B,), 0, jnp.int32)
    took = exit_conf >= thresholds[None, :]
    any_took = jnp.any(took, axis=1)
    first = jnp.argmax(took, axis=1)  # first True (argmax on bool)
    chosen = jnp.take_along_axis(exit_tok, first[:, None], axis=1)[:, 0]
    token = jnp.where(any_took, chosen, next_token)
    stage_idx = jnp.where(any_took, first, n_exits).astype(jnp.int32)
    return token, stage_idx


def make_prefill_step(cfg: ArchConfig, max_len: int):
    def prefill_step(params: Any, batch: dict, thresholds: jnp.ndarray):
        next_token, exit_conf, exit_tok, caches = model_lib.prefill(
            params, batch, cfg, max_len
        )
        token, stage_idx = select_exit(next_token, exit_conf, exit_tok, thresholds)
        return {
            "token": token,
            "exit_stage": stage_idx,
            "exit_conf": exit_conf,
            "caches": caches,
        }

    return prefill_step


def make_decode_step(cfg: ArchConfig):
    def decode_step(params: Any, batch: dict, caches: list, thresholds: jnp.ndarray):
        next_token, exit_conf, exit_tok, new_caches = model_lib.decode_step(
            params, batch, caches, cfg
        )
        token, stage_idx = select_exit(next_token, exit_conf, exit_tok, thresholds)
        return {
            "token": token,
            "exit_stage": stage_idx,
            "exit_conf": exit_conf,
            "caches": new_caches,
        }

    return decode_step
