"""jit-able serve steps: staged forwards, fused exit heads, prefill / decode.

These are the functions the serving engine and the multi-pod dry-run lower
for the inference shapes: static shapes, cache-in/cache-out, thresholds as a
traced vector so one compiled program serves every threshold setting DTO-EE
picks.  The per-stage builders below are what the micro-batched data plane
runs once per padded batch (jax re-traces per shape, so each builder yields
one compiled program per batch bucket).

The cache-threaded decode plane adds three per-stage programs:

  * ``make_stage_prefill`` — stage forward that also builds the stage's
    caches (one request row each);
  * ``make_slot_write``    — scatter a prefill batch's cache rows into the
    replica's slot-resident cache store;
  * ``make_stage_decode``  — one token per row against the slot store:
    gather the batch's slots, run the ragged cached decode (per-row
    positions, flash-decode attention kernel), scatter the rows back.

Slot stores are donated through the decode/write programs so XLA updates
them in place instead of copying the whole KV arena every token.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import model as model_lib


# ---------------------------------------------------------------------------
# Per-stage programs for the micro-batched engine
# ---------------------------------------------------------------------------


def make_embed_step(cfg: ArchConfig):
    """tokens [B, S] -> embedded residual stream [B, S, d]."""

    @jax.jit
    def embed_step(params: Any, tokens: jnp.ndarray) -> jnp.ndarray:
        return model_lib._embed_inputs(params, {"tokens": tokens}, cfg)

    return embed_step


def make_stage_forward(cfg: ArchConfig, stage_idx: int):
    """Residual stream through stage ``stage_idx`` (1-indexed), any batch."""

    @jax.jit
    def stage_forward(params: Any, x: jnp.ndarray) -> jnp.ndarray:
        stage = params["stages"][stage_idx - 1]
        positions = jnp.arange(x.shape[1], dtype=jnp.int32)
        out, _, _ = model_lib._run_stage(stage, x, cfg, positions, "train")
        return out

    return stage_forward


def make_exit_head_step(cfg: ArchConfig, stage_idx: int):
    """Fused (confidence, token) of exit branch b_h on x [B, S, d].

    The last-token slice happens inside the jitted program so the engine
    pays one device call per batch, not one per slice.
    """

    @jax.jit
    def exit_head_step(params: Any, x: jnp.ndarray):
        return model_lib.exit_confidence(params, x[:, -1:], stage_idx, cfg)

    return exit_head_step


def make_final_head_step(cfg: ArchConfig):
    """Fused (confidence, token) of the final head on x [B, S, d]."""

    @jax.jit
    def final_head_step(params: Any, x: jnp.ndarray):
        return model_lib.final_confidence(params, x[:, -1:], cfg)

    return final_head_step


def make_stage_prefill(cfg: ArchConfig, stage_idx: int, max_len: int):
    """Residual stream through stage ``stage_idx``, building its caches.

    Returns ``(x_out [B, S, d], stage_caches)`` with cache leaves shaped
    ``[n_periods, B, max_len, ...]`` — one row per request, ready to scatter
    into a replica's slot store.
    """

    @jax.jit
    def stage_prefill(params: Any, x: jnp.ndarray):
        return model_lib.prefill_stage(params, stage_idx, x, cfg, max_len)

    return stage_prefill


def make_slot_write(cfg: ArchConfig, stage_idx: int):
    """Scatter a prefill batch's cache rows into the slot store.

    ``slots`` is int32 [B]; padded rows point at the store's trash slot.
    The store is donated — on device the write is in-place.
    """

    @functools.partial(jax.jit, donate_argnums=(0,))
    def slot_write(slot_caches, new_caches, slots: jnp.ndarray):
        def wr(buf, new):
            # "pos" rows come out of prefill as one scalar per period
            # ([P]); everything else matches the store's rank
            if new.ndim < buf.ndim:
                new = new[..., None]
            return buf.at[:, slots].set(new.astype(buf.dtype))

        return jax.tree.map(wr, slot_caches, new_caches)

    return slot_write


def make_stage_decode(cfg: ArchConfig, stage_idx: int):
    """One cached decode token per row against the replica's slot store.

    ``x`` is the embedded/last-stage residual [B, 1, d]; ``slots`` int32 [B]
    names each row's cache slot.  Gathers the rows, runs the ragged decode
    (per-row positions; attention through ``kernels.ops.decode_attention``),
    scatters the updated rows back, and returns the stage output.  O(1) model
    FLOPs per token — the prefix is never recomputed.
    """

    @functools.partial(jax.jit, donate_argnums=(2,))
    def stage_decode(params: Any, x: jnp.ndarray, slot_caches, slots: jnp.ndarray):
        gathered = jax.tree.map(lambda a: jnp.take(a, slots, axis=1), slot_caches)
        x_out, new_rows = model_lib.decode_stage_ragged(
            params, stage_idx, x, gathered, cfg
        )
        new_store = jax.tree.map(
            lambda buf, new: buf.at[:, slots].set(new.astype(buf.dtype)),
            slot_caches,
            new_rows,
        )
        return x_out, new_store

    return stage_decode


def make_paged_slot_write(cfg: ArchConfig, stage_idx: int):
    """Scatter a prefill batch's cache rows into the PAGED slot store.

    ``wtab`` is int32 [B, n_logical] — each row's WRITE table: the physical
    pool block per logical block, with prefix-shared blocks (already filled,
    possibly read by other rows) and blocks past the prompt redirected to the
    pool's trash block; padded rows are all-trash.  Sequence-dim cache leaves
    are reshaped to block granularity and scattered through ``wtab``;
    per-slot leaves (``pos`` + SSM state) scatter at ``slots`` exactly like
    the dense layout.  Both stores are donated.
    """

    @functools.partial(jax.jit, donate_argnums=(0, 1))
    def paged_write(pool_stage, state_stage, new_caches, wtab, slots):
        new_pool, new_state = [], []
        flat_tab = wtab.reshape(-1)  # [B * n_logical]
        for pool_d, state_d, new_d in zip(pool_stage, state_stage, new_caches):
            pd = {}
            for key, buf in pool_d.items():
                new = new_d[key]  # [P, B, max_len, ...]
                P, B, L = new.shape[0], new.shape[1], new.shape[2]
                bs = buf.shape[2]
                pad = wtab.shape[1] * bs - L
                if pad:
                    new = jnp.pad(
                        new, ((0, 0), (0, 0), (0, pad)) + ((0, 0),) * (new.ndim - 3)
                    )
                new = new.reshape((P, B * wtab.shape[1], bs) + new.shape[3:])
                pd[key] = buf.at[:, flat_tab].set(new.astype(buf.dtype))
            sd = {}
            for key, buf in state_d.items():
                new = new_d[key]
                # "pos" comes out of prefill as one scalar per period ([P])
                if new.ndim < buf.ndim:
                    new = new[..., None]
                sd[key] = buf.at[:, slots].set(new.astype(buf.dtype))
            new_pool.append(pd)
            new_state.append(sd)
        return tuple(new_pool), tuple(new_state)

    return paged_write


def make_paged_stage_decode(cfg: ArchConfig, stage_idx: int, seq_len: int):
    """One cached decode token per row against the replica's PAGED store.

    ``tables`` int32 [B, n_logical] maps each row's logical blocks to pool
    rows (unallocated entries point at the trash block); ``slots`` int32 [B]
    names each row's per-slot state row.  Gathers the state rows, runs the
    ragged decode reading/writing KV through the block tables
    (``kernels.ops.paged_decode_attention``), scatters the state rows back,
    and returns the stage output.  The pool and state stores are donated.
    """

    @functools.partial(jax.jit, donate_argnums=(2, 3))
    def stage_decode(params, x, pool_stage, state_stage, tables, slots):
        rows = jax.tree.map(lambda a: jnp.take(a, slots, axis=1), state_stage)
        x_out, new_caches = model_lib.decode_stage_paged(
            params, stage_idx, x, pool_stage, rows, tables, cfg, seq_len
        )
        new_pool, new_state = [], []
        for pool_d, state_d, new_d in zip(pool_stage, state_stage, new_caches):
            new_pool.append({k: new_d[k] for k in pool_d})
            sd = {}
            for k, buf in state_d.items():
                sd[k] = buf.at[:, slots].set(new_d[k].astype(buf.dtype))
            new_state.append(sd)
        return x_out, tuple(new_pool), tuple(new_state)

    return stage_decode


def make_block_copy(cfg: ArchConfig, stage_idx: int):
    """Copy pool blocks ``src -> dst`` (int32 [n] each) across every
    sequence-dim leaf — the device half of allocator copy-on-write."""

    @functools.partial(jax.jit, donate_argnums=(0,))
    def block_copy(pool_stage, src, dst):
        return jax.tree.map(
            lambda buf: buf.at[:, dst].set(buf[:, src]), pool_stage
        )

    return block_copy


def select_exit(
    next_token: jnp.ndarray,  # [B] final-head tokens
    exit_conf: jnp.ndarray,  # [B, n_exits]
    exit_tok: jnp.ndarray,  # [B, n_exits]
    thresholds: jnp.ndarray,  # [n_exits]
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Paper's exit rule: first branch with conf >= c_h wins, else final head.

    Returns (token [B], exit_stage_index [B] — n_exits means the final head).
    """
    B, n_exits = exit_conf.shape
    if n_exits == 0:
        return next_token, jnp.full((B,), 0, jnp.int32)
    took = exit_conf >= thresholds[None, :]
    any_took = jnp.any(took, axis=1)
    first = jnp.argmax(took, axis=1)  # first True (argmax on bool)
    chosen = jnp.take_along_axis(exit_tok, first[:, None], axis=1)[:, 0]
    token = jnp.where(any_took, chosen, next_token)
    stage_idx = jnp.where(any_took, first, n_exits).astype(jnp.int32)
    return token, stage_idx


def make_prefill_step(cfg: ArchConfig, max_len: int):
    def prefill_step(params: Any, batch: dict, thresholds: jnp.ndarray):
        next_token, exit_conf, exit_tok, caches = model_lib.prefill(
            params, batch, cfg, max_len
        )
        token, stage_idx = select_exit(next_token, exit_conf, exit_tok, thresholds)
        return {
            "token": token,
            "exit_stage": stage_idx,
            "exit_conf": exit_conf,
            "caches": caches,
        }

    return prefill_step


def make_decode_step(cfg: ArchConfig):
    def decode_step(params: Any, batch: dict, caches: list, thresholds: jnp.ndarray):
        next_token, exit_conf, exit_tok, new_caches = model_lib.decode_step(
            params, batch, caches, cfg
        )
        token, stage_idx = select_exit(next_token, exit_conf, exit_tok, thresholds)
        return {
            "token": token,
            "exit_stage": stage_idx,
            "exit_conf": exit_conf,
            "caches": new_caches,
        }

    return decode_step


_MONO_PROGRAMS: dict = {}


def _monolithic_programs(cfg: ArchConfig, max_len: int):
    """Jitted ``model.prefill`` / ``model.decode_step`` for the reference
    generator, cached per (cfg, max_len) so repeated calls reuse programs."""
    key = (cfg, max_len)
    if key not in _MONO_PROGRAMS:
        _MONO_PROGRAMS[key] = (
            jax.jit(
                lambda params, batch: model_lib.prefill(params, batch, cfg, max_len)
            ),
            jax.jit(
                lambda params, batch, caches: model_lib.decode_step(
                    params, batch, caches, cfg
                )
            ),
        )
    return _MONO_PROGRAMS[key]


def monolithic_generate(
    params: Any,
    cfg: ArchConfig,
    prompt: np.ndarray,  # [S] int32
    thresholds: np.ndarray,  # [n_early_branches]
    gen_len: int,
    max_len: int | None = None,
) -> tuple[list[int], int]:
    """Single-host reference: ``model.prefill`` + ``model.decode_step``.

    Applies the paper's exit rule per token — the first early branch with
    conf >= c_b emits the token AND terminates the generation (a confident
    answer); otherwise the final head's token is appended and decoding
    continues up to ``gen_len``.  Returns ``(tokens, exit_stage_of_last)``.
    The staged engine's cache-threaded decode must be token-identical to
    this, which is what ``tests/test_decode_serving.py`` asserts.
    """
    S = int(prompt.shape[0])
    if max_len is None:
        max_len = S + gen_len
    exit_stages = list(cfg.exit_stages)
    H = cfg.num_stages

    def pick(conf, tok, final_tok):
        for b, stage in enumerate(exit_stages):
            if float(conf[0, b]) >= float(thresholds[b]):
                return int(tok[0, b]), stage
        return int(final_tok[0]), H

    prefill_fn, decode_fn = _monolithic_programs(cfg, max_len)
    batch = {"tokens": jnp.asarray(prompt[None], jnp.int32)}
    next_tok, conf, etok, caches = prefill_fn(params, batch)
    token, stage = pick(np.asarray(conf), np.asarray(etok), np.asarray(next_tok))
    tokens = [token]
    while stage == H and len(tokens) < gen_len:
        db = {"tokens": jnp.asarray([[tokens[-1]]], jnp.int32)}
        next_tok, conf, etok, caches = decode_fn(params, db, caches)
        token, stage = pick(np.asarray(conf), np.asarray(etok), np.asarray(next_tok))
        tokens.append(token)
    return tokens, stage
