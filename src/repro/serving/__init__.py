from repro.serving.batching import FifoBatcher, Request, pad_tokens
from repro.serving.engine import CollaborativeEngine, ServeStats, StagePrograms
from repro.serving.steps import make_decode_step, make_prefill_step, select_exit

__all__ = [
    "FifoBatcher", "Request", "pad_tokens",
    "CollaborativeEngine", "ServeStats", "StagePrograms",
    "make_decode_step", "make_prefill_step", "select_exit",
]
