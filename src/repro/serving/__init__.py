from repro.serving.batching import (
    ExitPredictor,
    FifoBatcher,
    Request,
    ShapeBucketBatcher,
    SlotRing,
    batch_tokens,
    pack_decode_batch,
    pad_tokens,
    padded_batch_size,
    pow2_floor,
)
from repro.serving.engine import CollaborativeEngine, ServeStats, StagePrograms
from repro.serving.paging import AllocResult, AppendResult, BlockAllocator, blocks_for
from repro.serving.steps import (
    make_block_copy,
    make_decode_step,
    make_embed_step,
    make_exit_head_step,
    make_final_head_step,
    make_paged_slot_write,
    make_paged_stage_decode,
    make_prefill_step,
    make_slot_write,
    make_stage_decode,
    make_stage_forward,
    make_stage_prefill,
    monolithic_generate,
    select_exit,
)

__all__ = [
    "ExitPredictor", "FifoBatcher", "Request", "ShapeBucketBatcher", "SlotRing",
    "batch_tokens", "pack_decode_batch", "pad_tokens", "padded_batch_size",
    "pow2_floor",
    "AllocResult", "AppendResult", "BlockAllocator", "blocks_for",
    "CollaborativeEngine", "ServeStats", "StagePrograms",
    "make_block_copy", "make_decode_step", "make_embed_step",
    "make_exit_head_step", "make_final_head_step", "make_paged_slot_write",
    "make_paged_stage_decode", "make_prefill_step", "make_slot_write",
    "make_stage_decode", "make_stage_forward", "make_stage_prefill",
    "monolithic_generate", "select_exit",
]
