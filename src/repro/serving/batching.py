"""Request queue + batcher for the collaborative serving engine.

Requests carry their token prompt and bookkeeping (arrival time, current
stage, exit status).  The batcher groups requests heading to the same stage
replica into fixed-size padded batches — static shapes for the jit'd stage
programs.

``ShapeBucketBatcher`` is the per-replica queue of the micro-batched data
plane: requests are bucketed by input shape (prompt length at stage 1, the
residual-stream shape beyond), each bucket is a ``FifoBatcher``, and batches
drain FIFO *across* buckets — the bucket holding the oldest waiting request
goes first, so an odd shape can't be starved by a hot one.
"""
from __future__ import annotations

import dataclasses
import itertools
from collections import deque
from typing import Any, Hashable

import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    tokens: np.ndarray  # prompt token ids
    arrival: float
    # runtime state
    stage: int = 0
    node: int = -1
    hidden: Any = None  # residual stream handed between stages
    exited: bool = False
    exit_stage: int = -1
    output_token: int = -1
    confidence: float = 0.0
    t_done: float = 0.0

    @property
    def delay(self) -> float:
        return self.t_done - self.arrival


class FifoBatcher:
    """Per-replica FIFO with fixed-batch draining."""

    def __init__(self, batch_size: int):
        self.batch_size = batch_size
        self.queue: deque[Request] = deque()

    def push(self, req: Request) -> None:
        self.queue.append(req)

    def drain(self, max_batches: int | None = None) -> list[list[Request]]:
        batches = []
        while self.queue and (max_batches is None or len(batches) < max_batches):
            take = min(self.batch_size, len(self.queue))
            batches.append([self.queue.popleft() for _ in range(take)])
        return batches

    def __len__(self) -> int:
        return len(self.queue)


class ShapeBucketBatcher:
    """Shape-bucketed FIFO batching for one replica.

    Each distinct input shape gets its own ``FifoBatcher``; ``pop_batch``
    serves the bucket whose head request has waited longest (FIFO across
    buckets), taking at most ``batch_size`` requests of that one shape so
    the padded batch stays rectangular.
    """

    def __init__(self, batch_size: int):
        self.batch_size = batch_size
        self.buckets: dict[Hashable, FifoBatcher] = {}
        self._seqs: dict[Hashable, deque[int]] = {}
        self._push_seq = itertools.count()

    def push(self, key: Hashable, req: Request) -> None:
        bucket = self.buckets.get(key)
        if bucket is None:
            bucket = self.buckets[key] = FifoBatcher(self.batch_size)
            self._seqs[key] = deque()
        bucket.push(req)
        self._seqs[key].append(next(self._push_seq))

    def pop_batch(self) -> tuple[Hashable, list[Request]] | None:
        """Drain one batch from the longest-waiting bucket, or None if idle."""
        heads = [(s[0], k) for k, s in self._seqs.items() if s]
        if not heads:
            return None
        _, key = min(heads)
        batch = self.buckets[key].drain(max_batches=1)[0]
        seqs = self._seqs[key]
        for _ in batch:
            seqs.popleft()
        return key, batch

    def __len__(self) -> int:
        return sum(len(b) for b in self.buckets.values())


def pad_tokens(reqs: list[Request], pad_id: int = 0) -> tuple[np.ndarray, np.ndarray]:
    """Right-pad prompts to a common length; returns (tokens [B, S], lengths [B])."""
    max_len = max(int(r.tokens.shape[0]) for r in reqs)
    B = len(reqs)
    out = np.full((B, max_len), pad_id, np.int32)
    lengths = np.zeros((B,), np.int32)
    for i, r in enumerate(reqs):
        n = int(r.tokens.shape[0])
        out[i, :n] = r.tokens
        lengths[i] = n
    return out, lengths


def padded_batch_size(n: int, batch_size: int) -> int:
    """Static batch dim for ``n`` live rows: next power of two, capped at
    ``batch_size`` — bounds the jit cache to log2(batch_size) entries per
    shape bucket while not paying the full batch for stragglers."""
    if n >= batch_size:
        return batch_size
    b = 1
    while b < n:
        b <<= 1
    return min(b, batch_size)


def batch_tokens(reqs: list[Request], batch_size: int, pad_id: int = 0) -> np.ndarray:
    """Stack same-length prompts into a padded [B, S] token batch."""
    toks, _ = pad_tokens(reqs, pad_id)
    B = padded_batch_size(len(reqs), batch_size)
    if B > len(reqs):
        toks = np.concatenate(
            [toks, np.full((B - len(reqs), toks.shape[1]), pad_id, np.int32)]
        )
    return toks
