"""Request queue + batcher for the collaborative serving engine.

Requests carry their token prompt and bookkeeping (arrival time, current
stage, exit status).  The batcher groups requests heading to the same stage
replica into fixed-size padded batches — static shapes for the jit'd stage
programs.

``ShapeBucketBatcher`` is the per-replica queue of the micro-batched data
plane: requests are bucketed by input shape (prompt length at stage 1, the
residual-stream shape beyond), each bucket is a ``FifoBatcher``, and batches
drain FIFO *across* buckets — the bucket holding the oldest waiting request
goes first, so an odd shape can't be starved by a hot one.
"""
from __future__ import annotations

import dataclasses
import itertools
from collections import deque
from typing import Any, Hashable

import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    tokens: np.ndarray  # prompt token ids
    arrival: float
    # runtime state
    stage: int = 0
    node: int = -1
    ed: int = -1  # arrival end device (failure re-submissions restart here)
    hidden: Any = None  # residual stream handed between stages
    exited: bool = False
    exit_stage: int = -1
    # execution attempts: 1 + number of fail-stop re-executions from the ED
    attempts: int = 1
    output_token: int = -1
    confidence: float = 0.0
    t_done: float = 0.0
    # autoregressive decode state
    phase: str = "prefill"  # "prefill" (first pass) | "decode" (cached steps)
    generated: list = dataclasses.field(default_factory=list)  # emitted tokens
    # per-stage route affinity: stage -> (node, edge); sampled on the first
    # pass and reused every decode step, so a request's stage-local KV cache
    # stays resident at the replica that built it
    path: dict = dataclasses.field(default_factory=dict)
    # stage-local cache residency: node -> slot index in that replica's ring
    slots: dict = dataclasses.field(default_factory=dict)
    # paged layout: node -> BlockAllocator sequence handle at that replica
    block_seq: dict = dataclasses.field(default_factory=dict)
    # latest observed confidence per early branch (previous token's reading;
    # the threshold-aware packer's exit predictor reads these)
    last_conf: dict = dataclasses.field(default_factory=dict)

    @property
    def delay(self) -> float:
        return self.t_done - self.arrival

    @property
    def prompt_len(self) -> int:
        return int(self.tokens.shape[0])

    def all_tokens(self) -> np.ndarray:
        """Prompt plus everything generated so far (the stateless-decode
        re-prefill input)."""
        if not self.generated:
            return self.tokens
        return np.concatenate(
            [self.tokens, np.asarray(self.generated, np.int32)]
        )


class FifoBatcher:
    """Per-replica FIFO with fixed-batch draining."""

    def __init__(self, batch_size: int):
        self.batch_size = batch_size
        self.queue: deque[Request] = deque()

    def push(self, req: Request) -> None:
        self.queue.append(req)

    def drain(self, max_batches: int | None = None) -> list[list[Request]]:
        batches = []
        while self.queue and (max_batches is None or len(batches) < max_batches):
            take = min(self.batch_size, len(self.queue))
            batches.append([self.queue.popleft() for _ in range(take)])
        return batches

    def __len__(self) -> int:
        return len(self.queue)


class ShapeBucketBatcher:
    """Shape-bucketed FIFO batching for one replica.

    Each distinct input shape gets its own ``FifoBatcher``; ``pop_batch``
    serves the bucket whose head request has waited longest (FIFO across
    buckets), taking at most ``batch_size`` requests of that one shape so
    the padded batch stays rectangular.
    """

    def __init__(self, batch_size: int, seq=None):
        self.batch_size = batch_size
        self.buckets: dict[Hashable, FifoBatcher] = {}
        self._seqs: dict[Hashable, deque[int]] = {}
        # ``seq`` lets several queues share one arrival counter, so FIFO
        # order is comparable across them (prefill buckets vs decode rows)
        self._push_seq = seq if seq is not None else itertools.count()

    def push(self, key: Hashable, req: Request) -> None:
        bucket = self.buckets.get(key)
        if bucket is None:
            bucket = self.buckets[key] = FifoBatcher(self.batch_size)
            self._seqs[key] = deque()
        bucket.push(req)
        self._seqs[key].append(next(self._push_seq))

    def head_seq(self) -> int | None:
        """Push sequence number of the longest-waiting request, or None."""
        heads = [s[0] for s in self._seqs.values() if s]
        return min(heads) if heads else None

    def peek(self) -> tuple[Hashable, Request] | None:
        """(bucket key, head request) the next ``pop_batch`` would serve —
        lets the engine size ``max_take`` (e.g. to free cache blocks) before
        committing to the pop."""
        heads = [(s[0], k) for k, s in self._seqs.items() if s]
        if not heads:
            return None
        _, key = min(heads)
        return key, self.buckets[key].queue[0]

    def head_len(self) -> int:
        """Queue length of the bucket the next ``pop_batch`` would serve
        (0 when idle) — lets a packing policy trim the take to an exact
        padded shape before committing to the pop."""
        head = self.peek()
        return len(self.buckets[head[0]].queue) if head is not None else 0

    def pop_batch(
        self, max_take: int | None = None
    ) -> tuple[Hashable, list[Request]] | None:
        """Drain one batch from the longest-waiting bucket, or None if idle.

        ``max_take`` caps the batch below ``batch_size`` (e.g. to the number
        of free cache slots at the replica); the rest of the bucket stays
        queued.
        """
        heads = [(s[0], k) for k, s in self._seqs.items() if s]
        if not heads:
            return None
        _, key = min(heads)
        take = self.batch_size if max_take is None else min(max_take, self.batch_size)
        if take < 1:
            return None
        bucket = self.buckets[key]
        batch = [bucket.queue.popleft() for _ in range(min(take, len(bucket.queue)))]
        seqs = self._seqs[key]
        for _ in batch:
            seqs.popleft()
        return key, batch

    def __len__(self) -> int:
        return sum(len(b) for b in self.buckets.values())


class SlotRing:
    """Ring allocator over a replica's cache slots.

    Freed slots rejoin at the tail, so allocation cycles through the ring —
    a retired request's rows are the last to be overwritten (friendly to
    debugging and to future prefix reuse).
    """

    def __init__(self, num_slots: int):
        if num_slots < 1:
            raise ValueError("num_slots must be >= 1")
        self.num_slots = num_slots
        self._free: deque[int] = deque(range(num_slots))

    @property
    def available(self) -> int:
        return len(self._free)

    def alloc(self) -> int | None:
        return self._free.popleft() if self._free else None

    def free(self, slot: int) -> None:
        if not (0 <= slot < self.num_slots):
            raise ValueError(f"slot {slot} out of range")
        if slot in self._free:
            raise ValueError(f"slot {slot} double-freed")
        self._free.append(slot)


def pad_tokens(reqs: list[Request], pad_id: int = 0) -> tuple[np.ndarray, np.ndarray]:
    """Right-pad prompts (plus any generated suffix) to a common length;
    returns (tokens [B, S], lengths [B])."""
    toks = [r.all_tokens() for r in reqs]
    max_len = max(int(t.shape[0]) for t in toks)
    B = len(reqs)
    out = np.full((B, max_len), pad_id, np.int32)
    lengths = np.zeros((B,), np.int32)
    for i, t in enumerate(toks):
        n = int(t.shape[0])
        out[i, :n] = t
        lengths[i] = n
    return out, lengths


def padded_batch_size(n: int, batch_size: int) -> int:
    """Static batch dim for ``n`` live rows: next power of two, capped at
    ``batch_size`` — bounds the jit cache to log2(batch_size) entries per
    shape bucket while not paying the full batch for stragglers."""
    if n >= batch_size:
        return batch_size
    b = 1
    while b < n:
        b <<= 1
    return min(b, batch_size)


def pow2_floor(n: int) -> int:
    """Largest power of two <= n (n >= 1) — the biggest batch that pads to
    exactly itself under ``padded_batch_size``."""
    if n < 1:
        raise ValueError("pow2_floor needs n >= 1")
    b = 1
    while b * 2 <= n:
        b <<= 1
    return b


class ExitPredictor:
    """Predicts a decode row's retirement class from the DTO-EE thresholds
    and the row's own confidence history (the threshold-aware batch policy).

    Exit decisions per token are fresh reads of the model's branch
    confidences, but confidences autocorrelate strongly across a request's
    tokens — a row whose last token's branch-``b`` confidence already sits
    within ``margin`` of the threshold ``c_b`` is very likely to clear it on
    an upcoming token.  Rows not near any threshold retire when their
    generation budget runs out, so their class is the remaining token count.

    ``thresholds_fn`` is read at every call: when the online controller
    swaps thresholds mid-serve, predictions follow immediately.
    """

    def __init__(self, thresholds_fn, gen_len: int, margin: float = 0.9):
        self.thresholds_fn = thresholds_fn
        self.gen_len = gen_len
        self.margin = margin

    def __call__(self, req: Request) -> Hashable:
        thresholds = self.thresholds_fn()
        for b in range(len(thresholds)):
            c = req.last_conf.get(b)
            if c is not None and c >= self.margin * float(thresholds[b]):
                return ("exit", b)
        return ("run", self.gen_len - len(req.generated))


def pack_decode_batch(
    items: list,
    batch_size: int,
    classify,
) -> tuple[list, list]:
    """Threshold-aware batch packing over a FIFO decode queue.

    ``items`` is the queue content, ``(seq, Request)`` pairs in FIFO order.
    The head row always dispatches (no starvation); the batch is filled
    first with rows sharing the head's predicted retirement class — so the
    whole batch tends to retire together instead of bleeding rows one at a
    time — then with the remaining rows in FIFO order.  When fewer rows than
    ``batch_size`` are available, the take is trimmed to the largest power
    of two so the padded shape holds zero dead rows (``padded_batch_size``
    pads to the next power of two; a 5-row batch would ship 3 padding rows).

    Returns ``(take, rest)`` with ``rest`` in the original FIFO order.
    """
    if not items:
        return [], []
    classes = [classify(r) for _, r in items]
    head_cls = classes[0]
    same = [it for it, c in zip(items, classes) if c == head_cls]
    other = [it for it, c in zip(items, classes) if c != head_cls]
    cand = (same + other)[:batch_size]
    n = len(cand)
    if n < batch_size:
        n = pow2_floor(n)
    taken = {id(it) for it in cand[:n]}
    take = cand[:n]
    rest = [it for it in items if id(it) not in taken]
    return take, rest


def batch_tokens(reqs: list[Request], batch_size: int, pad_id: int = 0) -> np.ndarray:
    """Stack same-length prompts into a padded [B, S] token batch."""
    toks, _ = pad_tokens(reqs, pad_id)
    B = padded_batch_size(len(reqs), batch_size)
    if B > len(reqs):
        toks = np.concatenate(
            [toks, np.full((B - len(reqs), toks.shape[1]), pad_id, np.int32)]
        )
    return toks
