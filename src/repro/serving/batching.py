"""Request queue + batcher for the collaborative serving engine.

Requests carry their token prompt and bookkeeping (arrival time, current
stage, exit status).  The batcher groups requests heading to the same stage
replica into fixed-size padded batches — static shapes for the jit'd stage
programs.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any

import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    tokens: np.ndarray  # prompt token ids
    arrival: float
    # runtime state
    stage: int = 0
    node: int = -1
    hidden: Any = None  # residual stream handed between stages
    exited: bool = False
    exit_stage: int = -1
    output_token: int = -1
    confidence: float = 0.0
    t_done: float = 0.0

    @property
    def delay(self) -> float:
        return self.t_done - self.arrival


class FifoBatcher:
    """Per-replica FIFO with fixed-batch draining."""

    def __init__(self, batch_size: int):
        self.batch_size = batch_size
        self.queue: deque[Request] = deque()

    def push(self, req: Request) -> None:
        self.queue.append(req)

    def drain(self, max_batches: int | None = None) -> list[list[Request]]:
        batches = []
        while self.queue and (max_batches is None or len(batches) < max_batches):
            take = min(self.batch_size, len(self.queue))
            batches.append([self.queue.popleft() for _ in range(take)])
        return batches

    def __len__(self) -> int:
        return len(self.queue)


def pad_tokens(reqs: list[Request], pad_id: int = 0) -> tuple[np.ndarray, np.ndarray]:
    """Right-pad prompts to a common length; returns (tokens [B, S], lengths [B])."""
    max_len = max(int(r.tokens.shape[0]) for r in reqs)
    B = len(reqs)
    out = np.full((B, max_len), pad_id, np.int32)
    lengths = np.zeros((B,), np.int32)
    for i, r in enumerate(reqs):
        n = int(r.tokens.shape[0])
        out[i, :n] = r.tokens
        lengths[i] = n
    return out, lengths
